"""Disk-backed cache tier + cache concurrency regressions.

Covers the persistent tier (round trip, promotion into memory, schema
versioning, corruption tolerance, mtime GC), the put-time report
validation, the lock-audited ``__len__``/stats reads under a
multi-thread hammer, the two-process ``cache_dir`` sharing acceptance
criterion (subprocess), and the CompileResult JSON wire form.
"""

import dataclasses
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.core.driver import Compiler, CompileResult
from repro.core.frontend.kernelgen import get_bench
from repro.core.frontend.stencil import lower_to_ptx
from repro.core.passes import (
    CacheStats,
    CompileCache,
    DiskCache,
    KernelReport,
    PipelineConfig,
)
from repro.core.passes import diskcache as diskcache_mod
from repro.core.ptx import print_kernel

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _kernel(name="vecadd"):
    return lower_to_ptx(get_bench(name).program)


def _key(kernel, tag="t"):
    return CompileCache.key(print_kernel(kernel), PipelineConfig(),
                            (tag,))


def _report(name="vecadd", **kw):
    return KernelReport(name=name, pass_times={"emulate-flows": 0.01},
                        **kw)


# ---------------------------------------------------------------------------
# disk tier basics
# ---------------------------------------------------------------------------

def test_disk_roundtrip_and_promotion(tmp_path):
    kernel = _kernel()
    key = _key(kernel)
    disk = DiskCache(tmp_path)
    first = CompileCache(disk=disk)
    first.put(key, kernel, _report())
    assert len(disk) == 1

    # a different CompileCache (a different process, conceptually)
    # sharing the directory: memory miss -> disk hit -> promoted
    second = CompileCache(disk=disk)
    got = second.get(key)
    assert got is not None
    out_kernel, out_report = got
    assert print_kernel(out_kernel) == print_kernel(kernel), \
        "disk round trip must be byte-identical"
    assert out_report.cached and out_report.name == "vecadd"
    stats = second.stats.snapshot()
    assert (stats.misses, stats.disk_hits, stats.disk_misses) == (1, 1, 0)
    # promotion: the next lookup is a pure memory hit
    assert second.get(key) is not None
    stats = second.stats.snapshot()
    assert (stats.hits, stats.disk_hits) == (1, 1)
    assert len(second) == 1


def test_disk_miss_counted_without_entry(tmp_path):
    cache = CompileCache(disk=DiskCache(tmp_path))
    assert cache.get("0" * 64) is None
    stats = cache.stats.snapshot()
    assert (stats.misses, stats.disk_hits, stats.disk_misses) == (1, 0, 1)


def test_schema_version_misses_cleanly(tmp_path, monkeypatch):
    kernel = _kernel()
    key = _key(kernel)
    disk = DiskCache(tmp_path)
    disk.store(key, kernel, _report())
    assert disk.load(key) is not None
    # a format bump re-keys the tree: old entries miss, nothing raises
    monkeypatch.setattr(diskcache_mod, "SCHEMA_VERSION",
                        diskcache_mod.SCHEMA_VERSION + 1)
    assert DiskCache(tmp_path).load(key) is None


def test_corrupt_entries_are_misses(tmp_path):
    kernel = _kernel()
    disk = DiskCache(tmp_path)
    for victim, garbage in (("report.pkl", b"\x80garbage"),
                            ("kernel.ptx", b"definitely not ptx {{{")):
        key = _key(kernel, tag=victim)
        disk.store(key, kernel, _report())
        (disk.path_for(key) / victim).write_bytes(garbage)
        assert disk.load(key) is None, f"corrupt {victim} must miss"
    # a report that unpickles to a non-dataclass is rejected too
    key = _key(kernel, tag="nondc")
    disk.store(key, kernel, _report())
    import pickle
    (disk.path_for(key) / "report.pkl").write_bytes(
        pickle.dumps({"not": "a dataclass"}))
    assert disk.load(key) is None


def test_gc_bounds_size_evicting_oldest_mtime(tmp_path):
    kernel = _kernel()
    disk = DiskCache(tmp_path, max_bytes=1)   # everything is over budget
    keys = [_key(kernel, tag=f"gc{i}") for i in range(3)]
    # store without triggering gc mid-test: stage entries by hand
    big = DiskCache(tmp_path, max_bytes=1 << 30)
    for i, key in enumerate(keys):
        big.store(key, kernel, _report())
        # spread mtimes so eviction order is deterministic
        os.utime(big.path_for(key), (1000 + i, 1000 + i))
    evicted = disk.gc()
    assert evicted == 3 and len(disk) == 0

    # partial bound: keep the newest entry only
    for i, key in enumerate(keys):
        big.store(key, kernel, _report())
        os.utime(big.path_for(key), (1000 + i, 1000 + i))
    entry_bytes = sum(f.stat().st_size
                      for f in big.path_for(keys[0]).iterdir())
    partial = DiskCache(tmp_path, max_bytes=entry_bytes)
    assert partial.gc() == 2
    assert partial.load(keys[2]) is not None, "newest mtime must survive"
    assert partial.load(keys[0]) is None and partial.load(keys[1]) is None


def test_store_serialization_failure_degrades_to_noop(tmp_path):
    """An unpicklable pass product must not take the compile down or
    leak a staging dir — persistence failures degrade to recompilation."""
    kernel = _kernel()
    disk = DiskCache(tmp_path)
    rep = _report()
    rep.detection = threading.Lock()       # unpicklable
    key = _key(kernel, tag="unpicklable")
    assert disk.store(key, kernel, rep) == 0
    assert disk.load(key) is None
    assert not any((tmp_path / "tmp").iterdir()), "staging dir leaked"


def test_gc_sweeps_orphaned_staging_dirs(tmp_path):
    """A writer killed mid-store leaves tmp/<uuid> behind; gc() must
    reap stale stages (but never fresh ones a live writer owns)."""
    disk = DiskCache(tmp_path)
    orphan = tmp_path / "tmp" / "deadbeef"
    orphan.mkdir()
    (orphan / "kernel.ptx").write_text("x")
    os.utime(orphan, (1, 1))               # ancient mtime
    fresh = tmp_path / "tmp" / "live"
    fresh.mkdir()
    disk.gc()
    assert not orphan.exists()
    assert fresh.exists()


def test_put_counts_disk_evictions_in_stats(tmp_path):
    kernel = _kernel()
    cache = CompileCache(disk=DiskCache(tmp_path, max_bytes=1))
    cache.put(_key(kernel, tag="a"), kernel, _report())
    assert cache.stats.snapshot().disk_evictions >= 1


def test_clear_keeps_disk_tier(tmp_path):
    kernel = _kernel()
    key = _key(kernel)
    cache = CompileCache(disk=DiskCache(tmp_path))
    cache.put(key, kernel, _report())
    cache.clear()
    assert len(cache) == 0
    assert cache.stats.snapshot() == CacheStats()
    got = cache.get(key)     # still served — from disk
    assert got is not None and got[1].cached
    assert cache.stats.snapshot().disk_hits == 1


# ---------------------------------------------------------------------------
# satellite bugfixes: put-time validation + locked len/stats
# ---------------------------------------------------------------------------

def test_put_rejects_non_dataclass_report(tmp_path):
    kernel = _kernel()
    for cache in (CompileCache(), CompileCache(disk=DiskCache(tmp_path))):
        with pytest.raises(TypeError, match="dataclass"):
            cache.put(_key(kernel), kernel, {"not": "a dataclass"})
        assert len(cache) == 0, "a rejected put must not insert"
    with pytest.raises(TypeError, match="dataclass"):
        DiskCache(tmp_path).store(_key(kernel), kernel, object())


def test_concurrent_get_put_clear_len_no_exceptions():
    """The __len__ / stats torn-read regression: hammer one cache with
    mixed operations from many threads; nothing may raise."""
    kernel = _kernel()
    cache = CompileCache(max_entries=8)
    keys = [_key(kernel, tag=f"k{i}") for i in range(16)]
    report = _report()
    errors = []
    stop = threading.Event()

    def hammer(tid):
        try:
            for i in range(300):
                op = (tid + i) % 5
                key = keys[(tid * 7 + i) % len(keys)]
                if op == 0:
                    cache.put(key, kernel, report)
                elif op == 1:
                    cache.get(key)
                elif op == 2:
                    assert len(cache) >= 0
                elif op == 3:
                    _ = cache.stats.summary, cache.stats.hit_rate
                else:
                    cache.clear()
        except Exception as e:  # noqa: BLE001
            errors.append(e)
            stop.set()

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors


def test_concurrent_stats_invariant_hits_plus_misses_is_lookups():
    """Without clear() in the mix, hits + misses must equal the exact
    number of lookups issued, and the eviction-adjusted entry count
    must match — counters may never tear or drop under concurrency."""
    kernel = _kernel()
    cache = CompileCache(max_entries=4)
    keys = [_key(kernel, tag=f"s{i}") for i in range(8)]
    report = _report()
    lookups_per_thread = 200
    n_threads = 8
    errors = []

    def hammer(tid):
        try:
            for i in range(lookups_per_thread):
                key = keys[(tid * 3 + i) % len(keys)]
                if (tid + i) % 3 == 0:
                    cache.put(key, kernel, report)
                cache.get(key)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    stats = cache.stats.snapshot()
    assert stats.hits + stats.misses == n_threads * lookups_per_thread
    assert len(cache) <= 4


def test_stats_snapshot_is_plain_and_consistent():
    cache = CompileCache()
    kernel = _kernel()
    cache.put(_key(kernel), kernel, _report())
    cache.get(_key(kernel))
    snap = cache.stats.snapshot()
    assert snap._lock is None, "snapshots are plain value objects"
    assert dataclasses.replace(snap).hits == snap.hits == 1
    assert "hits 1" in cache.stats.summary
    assert cache.stats.to_dict()["hits"] == 1


# ---------------------------------------------------------------------------
# two-process sharing (the acceptance criterion)
# ---------------------------------------------------------------------------

_CHILD = """
import json, sys
from repro.core.driver import Compiler
from repro.core.frontend.kernelgen import get_bench
with Compiler(cache_dir=sys.argv[1]) as cc:
    res = cc.compile(get_bench("vecadd"))
    print(json.dumps({
        "cached": res.cached,
        "ptx": res.ptx,
        "pass_times": cc.pass_times,
        "stats": cc.cache_stats.to_dict(),
    }))
"""


def _spawn(cache_dir):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _CHILD, str(cache_dir)],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout)


def test_two_processes_share_cache_dir_zero_reemulation(tmp_path):
    """Two Compiler sessions in separate processes sharing one
    cache_dir: the second must serve from disk with zero symbolic
    emulations and byte-identical PTX."""
    first = _spawn(tmp_path)
    assert not first["cached"]
    assert first["pass_times"].get("emulate-flows", 0) > 0, \
        "cold process must actually emulate"
    second = _spawn(tmp_path)
    assert second["cached"], "second process must be served from disk"
    assert second["stats"]["disk_hits"] == 1
    assert second["stats"]["disk_misses"] == 0
    assert "emulate-flows" not in second["pass_times"], \
        "a disk-served compile re-ran symbolic emulation"
    assert second["ptx"] == first["ptx"], "cross-process byte-identity"


def test_rejected_cache_dir_combinations(tmp_path):
    with pytest.raises(ValueError, match="cache_dir"):
        Compiler(cache_dir=str(tmp_path), share_global_cache=True)
    with pytest.raises(ValueError, match="cache_dir"):
        Compiler(cache_dir=str(tmp_path), cache=CompileCache())


# ---------------------------------------------------------------------------
# CompileResult JSON wire form
# ---------------------------------------------------------------------------

def test_compile_result_json_roundtrip():
    cc = Compiler()
    res = cc.compile(get_bench("jacobi"))
    wire = json.loads(json.dumps(res.to_json_dict()))
    back = CompileResult.from_json_dict(wire)
    assert back.ptx == res.ptx, "PTX must survive the wire byte-identical"
    assert back.n_shuffles == res.n_shuffles == 6
    assert back.by_kernel["jacobi"].detection.n_loads == 9
    assert [k.name for k in back.module.kernels] == ["jacobi"]
    assert back.options.max_delta == res.options.max_delta
    assert back.frontend == "kernelgen"
    assert len(back.diagnostics) == len(res.diagnostics)
    assert back.cache_stats.misses == res.cache_stats.misses
    # pass_times aggregates from the reports survive too
    assert set(back.pass_times) == set(res.pass_times)


def test_compile_result_json_schema_guard():
    cc = Compiler()
    wire = cc.compile(get_bench("vecadd")).to_json_dict()
    wire["schema"] = 999
    with pytest.raises(ValueError, match="schema"):
        CompileResult.from_json_dict(wire)
