"""Distributed-layer tests.  Multi-device cases run in subprocesses so
the main pytest process keeps a single CPU device (the dry-run policy:
never set xla_force_host_platform_device_count globally)."""

import subprocess
import sys
import textwrap

import pytest


def _run(src: str, devices: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, "src")
    """) + textwrap.dedent(src)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=__file__.rsplit("/tests", 1)[0],
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_ring_attention_matches_dense():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed import ring_attention
        from repro.models.attention import AttnConfig, naive_attention
        rng = np.random.default_rng(0)
        mesh = make_mesh((2, 4), ("data", "model"))
        B,S,H,KV,Dh = 2, 32, 4, 2, 16
        q = jnp.asarray(rng.standard_normal((B,S,H,Dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B,S,KV,Dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B,S,KV,Dh)), jnp.float32)
        cfg = AttnConfig(d_model=H*Dh, n_heads=H, n_kv_heads=KV, head_dim=Dh,
                         rope_theta=0, causal=True)
        ref = naive_attention(q, k, v, cfg)
        out = ring_attention(q, k, v, mesh, axis="model")
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_moe_sharded_matches_dense():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.models.moe import init_moe, apply_moe_dense, apply_moe_sharded
        from repro.models.common import unbox
        mesh = make_mesh((4, 2), ("data", "model"))
        E, k, D, F = 8, 2, 16, 32
        params = unbox(init_moe(jax.random.PRNGKey(0), D, F, E, k))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, D))
        y_ref, _ = apply_moe_dense(params, x, k, E)
        y_sh, _ = apply_moe_sharded(params, x, k, E, mesh,
                                    capacity_factor=float(E)/k)
        err = float(jnp.max(jnp.abs(y_ref - y_sh)))
        assert err < 1e-5, err
        # decode-like case: S=1 cannot shard over the tensor axis
        x1 = jax.random.normal(jax.random.PRNGKey(2), (8, 1, D))
        y_ref1, _ = apply_moe_dense(params, x1, k, E)
        y_sh1, _ = apply_moe_sharded(params, x1, k, E, mesh,
                                     capacity_factor=float(E)/k)
        err1 = float(jnp.max(jnp.abs(y_ref1 - y_sh1)))
        assert err1 < 1e-5, err1
        print("OK", err, err1)
    """)
    assert "OK" in out


def test_moe_capacity_drops_tokens_gracefully():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.models.moe import init_moe, apply_moe_sharded
        from repro.models.common import unbox
        mesh = make_mesh((4, 2), ("data", "model"))
        E, k, D, F = 8, 2, 16, 32
        params = unbox(init_moe(jax.random.PRNGKey(0), D, F, E, k))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, D))
        # tiny capacity: result finite, not exact (drops are zeros)
        y, aux = apply_moe_sharded(params, x, k, E, mesh, capacity_factor=0.5)
        assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.isfinite(aux))
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed import pipeline_apply
        rng = np.random.default_rng(0)
        mesh = make_mesh((4,), ("stage",))
        W = jnp.asarray(rng.standard_normal((4, 8, 8)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.standard_normal((6, 3, 8)), jnp.float32)
        out = pipeline_apply(lambda w, x: jnp.tanh(x @ w), W, x, mesh)
        seq = x
        for i in range(4):
            seq = jnp.tanh(seq @ W[i])
        err = float(jnp.max(jnp.abs(out - seq)))
        assert err < 1e-6, err
        print("OK", err)
    """, devices=4)
    assert "OK" in out


def test_compression_bounds_and_ef():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed import pod_compressed_mean, ef_compressed_mean
        rng = np.random.default_rng(0)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = {"w": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)}
        gm = pod_compressed_mean(g, mesh)
        # replicated grads: compressed mean == identity up to quant step
        bound = float(jnp.max(jnp.abs(g["w"]))) / 127 + 1e-7
        err = float(jnp.max(jnp.abs(gm["w"] - g["w"])))
        assert err <= bound, (err, bound)
        r0 = jax.tree_util.tree_map(jnp.zeros_like, g)
        gm2, r1 = ef_compressed_mean(g, r0, mesh)
        # EF invariant: sent + residual == corrected signal
        sent = gm2["w"]   # equals dequantized send here (identical pods)
        np.testing.assert_allclose(np.asarray(sent + r1["w"]),
                                   np.asarray(g["w"]), rtol=1e-5, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_fsdp_constraint_keeps_batch_sharded():
    """Regression for the 75GB/device dry-run bug: activations inside the
    layer scan must stay batch-sharded (constrain_batch)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.configs import get_config, reduced
        from repro.models import build_model, unbox
        from repro.sharding import param_shardings, batch_sharding
        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config("olmo-1b")).replace(d_model=64, n_layers=2)
        model = build_model(cfg, mesh)
        boxed = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params = unbox(model.init(jax.random.PRNGKey(0)))
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                 "labels": jnp.zeros((8, 32), jnp.int32)}
        loss, _ = jax.jit(model.loss)(params, batch)
        assert bool(jnp.isfinite(loss))
        print("OK")
    """)
    assert "OK" in out


def test_ring_attention_model_integration():
    """attn_impl='ring' (starcoder2's default) must equal blockwise
    through the full model path."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.configs import get_config, reduced
        from repro.models import build_model, unbox
        mesh = make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        cfg0 = reduced(get_config("starcoder2-3b")).replace(q_block=8,
                                                            kv_block=8)
        B, S = 4, 32
        toks = jnp.asarray(rng.integers(0, cfg0.vocab, (B, S)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        outs = {}
        for impl in ("blockwise", "ring"):
            model = build_model(cfg0.replace(attn_impl=impl), mesh)
            params = unbox(model.init(jax.random.PRNGKey(0)))
            h, _ = jax.jit(model.hidden)(params, batch)
            outs[impl] = np.asarray(h)
        err = np.max(np.abs(outs["ring"] - outs["blockwise"]))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_moe_dshard_matches_dense():
    """The 2d_dshard schedule (kimi-class: F < D) must equal the dense
    oracle when capacity is unconstrained."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.models.moe import init_moe, apply_moe_dense, apply_moe_sharded
        from repro.models.common import unbox
        mesh = make_mesh((4, 2), ("data", "model"))
        E, k, D, F = 8, 2, 16, 8        # F < D: the dshard regime
        params = unbox(init_moe(jax.random.PRNGKey(0), D, F, E, k))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, D))
        y_ref, _ = apply_moe_dense(params, x, k, E)
        y_ds, _ = apply_moe_sharded(params, x, k, E, mesh,
                                    capacity_factor=float(E)/k,
                                    schedule="2d_dshard")
        err = float(jnp.max(jnp.abs(y_ref - y_ds)))
        assert err < 1e-5, err
        # auto rule picks dshard in this regime unless ep_tp qualifies
        from repro.models.moe import choose_schedule
        class M: shape = {"data": 16, "model": 16}
        assert choose_schedule(384, 7168, 2048, M()) == "2d_dshard"
        assert choose_schedule(32, 1024, 512, M()) == "ep_tp"
        print("OK", err)
    """)
    assert "OK" in out
