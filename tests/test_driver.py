"""Driver-facade tests: polymorphic Source ingestion, byte-identity
with the legacy free-function/ptxasw paths, variants vs
compile_for_targets, session-cache scoping, the batched/async serving
path under concurrent load, conflicting-argument errors, and the
one-shot deprecation warning on the ptxasw wrappers."""

import concurrent.futures
import threading
import warnings

import pytest

import repro.core.passes.analyses as analyses_mod
import repro.core.synthesis.pipeline as legacy_pipeline
from repro.core.driver import (
    Compiler,
    CompilerOptions,
    frontend_names,
    normalize_source,
)
from repro.core.emulator.machine import emulate
from repro.core.frontend.kernelgen import get_bench
from repro.core.frontend.stencil import lower_to_ptx
from repro.core.passes import (
    GLOBAL_CACHE,
    PassPipeline,
    PipelineConfig,
    analyze_kernel,
    compile_for_targets,
    compile_kernel,
    compile_module,
    compile_ptx,
)
from repro.core.ptx import parse, print_kernel
from repro.core.synthesis.pipeline import ptxasw, ptxasw_kernel


def _jacobi_kernel():
    return lower_to_ptx(get_bench("jacobi").program)


def _count_emulate(monkeypatch):
    calls = []

    def counting(kernel, **kw):
        calls.append(kernel.name)
        return emulate(kernel, **kw)

    monkeypatch.setattr(analyses_mod, "emulate", counting)
    return calls


# ---------------------------------------------------------------------------
# Source ingestion: every form, byte-identical output
# ---------------------------------------------------------------------------

def test_all_source_forms_byte_identical():
    """PTX text, Module, Kernel, stencil Program and KernelGen Bench
    must produce byte-identical PTX through one Compiler.compile."""
    bench = get_bench("jacobi")
    kernel = lower_to_ptx(bench.program)
    text = print_kernel(kernel)
    sources = {
        "ptx": text,
        "module": parse(text),
        "kernel": kernel,
        "stencil": bench.program,
        "kernelgen": bench,
    }
    cc = Compiler()
    results = {name: cc.compile(src) for name, src in sources.items()}
    ptxs = {res.ptx for res in results.values()}
    assert len(ptxs) == 1, "source forms diverged"
    for name, res in results.items():
        assert res.frontend == name
        assert res.reports[0].detection.n_shuffles == 6


def test_frontend_registry_contents_and_unknown_source():
    assert set(frontend_names()) >= {"ptx", "module", "kernel",
                                     "stencil", "kernelgen"}
    with pytest.raises(TypeError, match="no frontend accepts"):
        normalize_source(12345)


def test_bench_ingestion_applies_max_delta_hint():
    """hypterm carries the paper's |N|<=1 restriction on the Bench; the
    kernelgen frontend must apply it when the caller sets nothing."""
    bench = get_bench("hypterm")
    assert bench.max_delta == 1
    cc = Compiler()
    res = cc.compile(bench, cache=None)
    assert res.reports[0].detection.n_shuffles == 12     # paper: 12/48
    assert any("max_delta" in d.message for d in res.diagnostics)
    # an explicit caller setting beats the hint: at |N|<=31 the 3-wide
    # rows each cover two deltas instead of one, so detection grows
    res31 = cc.compile(bench, max_delta=31, cache=None)
    assert res31.options.max_delta == 31
    assert res31.reports[0].detection.n_shuffles > 12


# ---------------------------------------------------------------------------
# byte-identity with the legacy paths (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["jacobi", "gaussblur", "laplacian",
                                  "whispering", "wave13pt"])
def test_compiler_matches_legacy_paths(name):
    bench = get_bench(name)
    kernel = lower_to_ptx(bench.program)
    text = print_kernel(kernel)
    res = Compiler().compile(text, max_delta=bench.max_delta, cache=None)
    legacy_text, _ = compile_ptx(
        text, PipelineConfig(max_delta=bench.max_delta), cache=None)
    assert res.ptx == legacy_text
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        wrapper_text, _ = ptxasw(text, max_delta=bench.max_delta)
    assert res.ptx == wrapper_text


def test_variants_matches_compile_for_targets():
    text = print_kernel(_jacobi_kernel())
    mine = Compiler().variants(text, selection="cost", cache=None)
    legacy = compile_for_targets(text, selection="cost", cache=None)
    assert set(mine) == set(legacy)
    for name in mine:
        assert mine[name].ptx == legacy[name].ptx, name
        assert mine[name].n_shuffles == legacy[name].n_shuffles
        assert mine[name].target_profile.name == legacy[name].target.name


def test_variants_shares_analysis_prefix(monkeypatch):
    calls = _count_emulate(monkeypatch)
    cc = Compiler()
    cc.variants(print_kernel(_jacobi_kernel()),
                targets=["kepler", "pascal", "volta"])
    assert len(calls) == 1, "N targets must cost one symbolic emulation"


# ---------------------------------------------------------------------------
# session scoping: cache + options + pool
# ---------------------------------------------------------------------------

def test_session_cache_is_private_by_default():
    kernel = _jacobi_kernel()
    before = (GLOBAL_CACHE.stats.hits, GLOBAL_CACHE.stats.misses)
    cc = Compiler()
    assert cc.cache is not GLOBAL_CACHE
    res1 = cc.compile(kernel)
    res2 = cc.compile(kernel)
    assert not res1.cached and res2.cached
    assert (GLOBAL_CACHE.stats.hits, GLOBAL_CACHE.stats.misses) == before, \
        "a private session leaked into the process-wide cache"
    assert cc.cache_stats.hits == 1 and cc.cache_stats.misses == 1


def test_share_global_cache_opt_in():
    assert Compiler(share_global_cache=True).cache is GLOBAL_CACHE


def test_session_options_and_per_call_overrides():
    cc = Compiler(selection="cost", target="pascal")
    res = cc.compile(_jacobi_kernel(), cache=None)
    assert res.reports[0].selection is not None
    assert res.reports[0].target == "pascal"
    res2 = cc.compile(_jacobi_kernel(), target="volta", cache=None)
    assert res2.reports[0].target == "volta"
    # config= and field overrides are mutually exclusive
    with pytest.raises(ValueError, match="not both"):
        cc.compile(_jacobi_kernel(), PipelineConfig(), target="volta")
    with pytest.raises(ValueError, match="not both"):
        Compiler(CompilerOptions(), jobs=2)
    with pytest.raises(TypeError, match="unknown CompilerOptions field"):
        cc.compile(_jacobi_kernel(), no_such_option=1)


def test_compile_result_structure():
    cc = Compiler()
    res = cc.compile(print_kernel(_jacobi_kernel()))
    assert res.by_kernel["jacobi"].detection.n_shuffles == 6
    assert res.n_shuffles == 6
    assert set(res.pass_times) == {"emulate-flows", "detect-shuffles",
                                   "select-shuffles", "synthesize-shuffles"}
    assert res.wall_time_s > 0
    from repro.core.driver import Severity
    assert res.diagnostics, "driver must attach at least the routing note"
    assert not res.diagnostics_at(Severity.ERROR)
    assert res.cache_stats.misses == 1
    assert "compile" in res.summary and "1 kernel" in res.summary
    ana = cc.analyze(print_kernel(_jacobi_kernel()))
    assert ana.analysis_only and ana.ptx  # analysis passes kernel through
    assert ana.reports[0].detection.n_shuffles == 6


def test_session_pass_time_aggregation():
    cc = Compiler()
    cc.compile(_jacobi_kernel(), cache=None)
    cc.compile(lower_to_ptx(get_bench("laplacian").program), cache=None)
    times = cc.pass_times
    assert times["emulate-flows"] > 0 and times["synthesize-shuffles"] > 0
    assert cc.n_runs == 2


def test_cache_hits_do_not_inflate_session_pass_times():
    """A hit's report snapshots the original run's timings; the session
    aggregate must not re-count them once per hit."""
    cc = Compiler()
    cc.compile(_jacobi_kernel())
    after_miss = cc.pass_times
    for _ in range(5):
        assert cc.compile(_jacobi_kernel()).cached
    assert cc.pass_times == after_miss, \
        "cached compiles added phantom pass time"
    assert cc.n_runs == 6


def test_session_level_explicit_option_beats_source_hint():
    """Any field the session constructor was handed is an explicit
    choice: a Bench's max_delta hint must not override it — even when
    the handed value equals the default."""
    bench = get_bench("hypterm")           # carries max_delta=1 hint
    res = Compiler(max_delta=5).compile(bench, cache=None)
    assert res.options.max_delta == 5
    res31 = Compiler(max_delta=31).compile(bench, cache=None)
    assert res31.options.max_delta == 31, \
        "an explicitly-passed default value was treated as unset"
    # a full options= object counts as choosing every field
    res_opts = Compiler(CompilerOptions()).compile(bench, cache=None)
    assert res_opts.options.max_delta == 31
    # untouched session default: the hint applies
    res_default = Compiler().compile(bench, cache=None)
    assert res_default.options.max_delta == 1


def test_session_ignores_process_wide_default_jobs():
    """Compiler sessions must not inherit the deprecated
    set_default_jobs() global (session isolation)."""
    from repro.core.passes import set_default_jobs
    import repro.core.passes.manager as manager_mod
    texts = [print_kernel(lower_to_ptx(get_bench(n).program))
             for n in ("jacobi", "laplacian")]
    module_text = "\n".join(texts)
    set_default_jobs(1)
    try:
        seen = []
        orig = PassPipeline.run_module

        def spy(self, module, jobs=None, cache=None):
            seen.append(jobs)
            return orig(self, module, jobs=jobs, cache=cache)

        PassPipeline.run_module = spy
        try:
            Compiler().compile(module_text, cache=None)
        finally:
            PassPipeline.run_module = orig
        assert seen and all(j is not None for j in seen), \
            "a None jobs= reached run_module and picked up the global"
        assert manager_mod._DEFAULT_JOBS == 1   # global untouched
    finally:
        set_default_jobs(None)


def test_cache_and_share_global_cache_conflict():
    from repro.core.passes import CompileCache
    with pytest.raises(ValueError, match="not both"):
        Compiler(share_global_cache=True, cache=CompileCache())


def test_construction_only_knobs_rejected_per_call():
    """Session-cache knobs are fixed at construction; a per-call
    override could only be silently ignored, so it raises instead —
    whether passed as a kwarg or smuggled in via config=CompilerOptions."""
    cc = Compiler()
    with pytest.raises(ValueError, match="Compiler construction"):
        cc.compile(_jacobi_kernel(), share_global_cache=True)
    with pytest.raises(ValueError, match="Compiler construction"):
        cc.compile(_jacobi_kernel(), cache_entries=16)
    with pytest.raises(ValueError, match="Compiler construction"):
        cc.compile(_jacobi_kernel(),
                   CompilerOptions(share_global_cache=True))
    with pytest.raises(ValueError, match="Compiler construction"):
        cc.compile(_jacobi_kernel(), CompilerOptions(cache_entries=7))
    # default-valued fields on a per-call options object are not a
    # deliberate choice: they inherit the session's cache setup
    shared = Compiler(share_global_cache=True)
    res = shared.compile(_jacobi_kernel(), CompilerOptions(), cache=None)
    assert res.options.share_global_cache, \
        "per-call options reset the session's construction-only knobs"


def test_list_valued_passes_normalized_to_tuple():
    """CompilerOptions coerces any sequence to a tuple, so passes=
    stays hashable in compile_many's dedup key."""
    opts = CompilerOptions(passes=["emulate-flows", "detect-shuffles"])
    assert opts.passes == ("emulate-flows", "detect-shuffles")
    cc = Compiler()
    results = cc.compile_many([_jacobi_kernel(), _jacobi_kernel()],
                              passes=["emulate-flows", "detect-shuffles"])
    assert len(results) == 2 and results[1].cached
    cc.close()


def test_jobs_zero_means_minimal_pool():
    cc = Compiler(jobs=0)
    fut = cc.submit(print_kernel(_jacobi_kernel()))
    assert fut.result(timeout=120).n_shuffles == 6
    assert cc._executor._max_workers == 1
    cc.close()


def test_variants_rejects_passes_override():
    text = print_kernel(_jacobi_kernel())
    with pytest.raises(ValueError, match="passes= override"):
        Compiler().variants(text, passes=("emulate-flows",))
    with pytest.raises(ValueError, match="passes= override"):
        Compiler(passes=("emulate-flows",)).variants(text)


def test_analyze_honors_passes_override():
    cc = Compiler(passes=("emulate-flows",))
    res = cc.analyze(_jacobi_kernel(), cache=None)
    assert res.reports[0].detection is None, \
        "analyze() ignored the session passes override"
    res2 = Compiler().analyze(_jacobi_kernel(), cache=None,
                              passes=("emulate-flows", "detect-shuffles"))
    assert res2.reports[0].detection.n_shuffles == 6


# ---------------------------------------------------------------------------
# batched / async serving path
# ---------------------------------------------------------------------------

def test_compile_many_dedupes_distinct_kernels(monkeypatch):
    calls = _count_emulate(monkeypatch)
    jac = get_bench("jacobi")
    lap = get_bench("laplacian")
    cc = Compiler(jobs=4)
    results = cc.compile_many([jac, lap, jac, jac, lap, jac])
    assert len(results) == 6
    assert len(calls) == 2, "one emulate/detect per distinct kernel"
    assert results[0].ptx == results[2].ptx == results[3].ptx
    assert results[1].ptx == results[4].ptx
    # duplicate results are isolated copies served through the cache
    assert results[2].cached and results[5].cached
    cc.close()


def test_submit_concurrent_threads_one_session_cache():
    """Hammer submit() from concurrent threads against one session."""
    benches = [get_bench(n) for n in
               ("jacobi", "laplacian", "gradient", "vecadd")]
    serial = {b.program.name: Compiler().compile(b, cache=None).ptx
              for b in benches}
    cc = Compiler(jobs=8)
    for b in benches:          # warm the session cache deterministically
        cc.compile(b)
    n_client_threads, per_thread = 8, 12
    errors = []

    def client(tid: int):
        try:
            futures = [cc.submit(benches[(tid + i) % len(benches)])
                       for i in range(per_thread)]
            for i, fut in enumerate(futures):
                res = fut.result(timeout=120)
                want = benches[(tid + i) % len(benches)].program.name
                assert res.reports[0].name == want
                assert res.ptx == serial[want], f"corrupt result for {want}"
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_client_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    cc.close()
    assert not errors, errors
    stats = cc.cache_stats
    # warm-up missed once per distinct kernel; with the cache warm,
    # every concurrent request must be served from it
    assert stats.misses == len(benches)
    assert stats.hits == n_client_threads * per_thread


def test_submit_returns_future():
    cc = Compiler()
    fut = cc.submit(print_kernel(_jacobi_kernel()))
    assert isinstance(fut, concurrent.futures.Future)
    assert fut.result(timeout=120).n_shuffles == 6
    cc.close()
    cc.close()     # idempotent


# ---------------------------------------------------------------------------
# legacy shims: conflict wart + signatures + deprecation
# ---------------------------------------------------------------------------

def test_conflicting_config_and_pipeline_raise():
    kernel = _jacobi_kernel()
    cfg, pipe = PipelineConfig(), PassPipeline()
    with pytest.raises(ValueError, match="config= or pipeline="):
        compile_kernel(kernel, cfg, pipeline=pipe)
    with pytest.raises(ValueError, match="config= or pipeline="):
        compile_module(parse(print_kernel(kernel)), cfg, pipeline=pipe)
    with pytest.raises(ValueError, match="config= or pipeline="):
        analyze_kernel(kernel, cfg, pipeline=pipe)


def test_analyze_kernel_sibling_signature():
    """analyze_kernel accepts the same pipeline=/jobs= kwargs as its
    compile_* siblings."""
    kernel = _jacobi_kernel()
    rep = analyze_kernel(kernel, jobs=2, cache=None)
    assert rep.detection.n_shuffles == 6
    from repro.core.passes import ANALYSIS_PASSES
    rep2 = analyze_kernel(kernel, cache=None,
                          pipeline=PassPipeline(passes=ANALYSIS_PASSES))
    assert rep2.detection.n_shuffles == 6


def test_ptxasw_wrappers_warn_once(monkeypatch):
    monkeypatch.setattr(legacy_pipeline, "_warned", False)
    kernel = _jacobi_kernel()
    with pytest.warns(DeprecationWarning, match="Compiler"):
        ptxasw_kernel(kernel)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ptxasw(print_kernel(kernel))       # one-shot: second call silent
