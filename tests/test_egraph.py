"""E-graph core and rewrite-rule tests (PR 7).

Property tests (hypothesis when installed, skipped via the stub
otherwise) pin the structural invariants: union-find/congruence
consistency after arbitrary unions, rebuild idempotence, and rule
termination under the saturation budgets.  Each property also has a
seeded deterministic twin so the invariants are exercised even without
hypothesis, plus targeted unit tests for the individual rewrite rules.
"""

from __future__ import annotations

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_stub import given, settings, st

from repro.core.egraph import EGraph, ENode, default_rules
from repro.core.egraph.rules import _fold, _mask
from repro.core.egraph.saturate import MAX_ITERS, saturate_block


def _sym(eg, name, width=32):
    return eg.add(ENode("sym", width, (), ("in", name)))


def _const(eg, value, width=32):
    return eg.add(ENode("const", width, (), _mask(value, width)))


# ---------------------------------------------------------------------------
# core structure
# ---------------------------------------------------------------------------

def test_hashcons_dedups():
    eg = EGraph()
    a = _sym(eg, "%r1")
    b = _sym(eg, "%r2")
    n1 = eg.add(ENode("add", 32, (a, b)))
    n2 = eg.add(ENode("add", 32, (a, b)))
    assert n1 == n2
    assert eg.n_classes == 3
    # payload and width participate in identity
    assert eg.add(ENode("add", 64, (a, b))) != n1
    assert _sym(eg, "%r1") == a


def test_union_keeps_smallest_id_as_root():
    eg = EGraph()
    a = _sym(eg, "a")
    b = _sym(eg, "b")
    assert eg.union(b, a) is True
    assert eg.find(b) == a
    assert eg.union(a, b) is False      # already merged
    assert eg.n_unions == 1


def test_congruence_closure_after_union():
    """union(a, b) must merge f(a) with f(b) after rebuild."""
    eg = EGraph()
    a = _sym(eg, "a")
    b = _sym(eg, "b")
    fa = eg.add(ENode("not", 32, (a,)))
    fb = eg.add(ENode("not", 32, (b,)))
    gfa = eg.add(ENode("neg", 32, (fa,)))
    gfb = eg.add(ENode("neg", 32, (fb,)))
    assert fa != fb and gfa != gfb
    eg.union(a, b)
    eg.rebuild()
    assert eg.find(fa) == eg.find(fb)   # one hop
    assert eg.find(gfa) == eg.find(gfb)  # transitively, via fixpoint
    eg.check_invariants()


def test_rebuild_idempotent():
    eg = EGraph()
    a, b, c = (_sym(eg, n) for n in "abc")
    eg.add(ENode("add", 32, (a, b)))
    eg.add(ENode("add", 32, (a, c)))
    eg.union(b, c)
    assert eg.rebuild() > 0
    assert eg.rebuild() == 0            # immediately idempotent
    eg.check_invariants()


def test_add_after_union_hits_merged_class():
    """Hashcons canonicalizes children, so congruence holds for nodes
    added *after* their children merged, without a rebuild."""
    eg = EGraph()
    a = _sym(eg, "a")
    b = _sym(eg, "b")
    fa = eg.add(ENode("not", 32, (a,)))
    eg.union(a, b)
    fb = eg.add(ENode("not", 32, (b,)))
    assert eg.find(fa) == eg.find(fb)


def test_const_survives_union():
    eg = EGraph()
    c = _const(eg, 42)
    s = _sym(eg, "x")
    eg.union(c, s)
    assert eg.const_of(s) == 42
    assert eg.const_of(c) == 42


# ---------------------------------------------------------------------------
# property: random unions keep the invariants, rebuild is idempotent
# ---------------------------------------------------------------------------

def _random_graph(rng, n_leaves, n_ops, n_unions):
    """Grow a random DAG e-graph and perform random unions."""
    eg = EGraph()
    cids = [_sym(eg, f"v{i}") for i in range(n_leaves)]
    cids += [_const(eg, rng.randrange(0, 8)) for _ in range(2)]
    for _ in range(n_ops):
        op = rng.choice(["add", "mul", "and", "xor", "not"])
        if op == "not":
            ch = (rng.choice(cids),)
        else:
            ch = (rng.choice(cids), rng.choice(cids))
        cids.append(eg.add(ENode(op, 32, ch)))
    for _ in range(n_unions):
        eg.union(rng.choice(cids), rng.choice(cids))
    return eg, cids


@pytest.mark.parametrize("seed", range(8))
def test_random_unions_keep_invariants(seed):
    rng = random.Random(seed)
    eg, _ = _random_graph(rng, n_leaves=4, n_ops=20, n_unions=6)
    eg.rebuild()
    eg.check_invariants()
    assert eg.rebuild() == 0


@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
@settings(max_examples=50, deadline=None)
def test_property_random_unions(seed):
    rng = random.Random(seed)
    eg, cids = _random_graph(rng, n_leaves=5, n_ops=30, n_unions=10)
    eg.rebuild()
    eg.check_invariants()
    assert eg.rebuild() == 0
    # union-find sanity: find is a projection (find(find(x)) == find(x))
    for cid in cids:
        assert eg.find(eg.find(cid)) == eg.find(cid)


# ---------------------------------------------------------------------------
# property: saturation terminates under budget and keeps invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_saturation_terminates_under_budget(seed):
    rng = random.Random(1000 + seed)
    eg, _ = _random_graph(rng, n_leaves=4, n_ops=25, n_unions=4)
    counters = saturate_block(eg, default_rules())
    assert counters["iterations"] <= MAX_ITERS
    eg.check_invariants()
    # saturating an already saturated graph is a no-op (unless the
    # budget cut the first run short)
    if not counters["budget_hits"]:
        again = saturate_block(eg, default_rules())
        assert again["applied"] == 0


@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
@settings(max_examples=25, deadline=None)
def test_property_saturation_terminates(seed):
    rng = random.Random(seed)
    eg, _ = _random_graph(rng, n_leaves=4, n_ops=20, n_unions=5)
    counters = saturate_block(eg, default_rules(), max_iters=6,
                              max_nodes=2048)
    assert counters["iterations"] <= 6
    eg.check_invariants()


def test_node_budget_trips():
    """A tiny node budget must stop rule application and be counted."""
    eg = EGraph()
    a = _sym(eg, "a")
    acc = a
    for i in range(6):
        acc = eg.add(ENode("add", 32, (acc, _sym(eg, f"x{i}"))))
    counters = saturate_block(eg, default_rules(), max_nodes=8)
    assert counters["budget_hits"] == 1


# ---------------------------------------------------------------------------
# rewrite rules
# ---------------------------------------------------------------------------

def _saturated(build):
    eg = EGraph()
    out = build(eg)
    saturate_block(eg, default_rules())
    return eg, out


def test_const_fold_add():
    eg, cid = _saturated(lambda eg: eg.add(
        ENode("add", 32, (_const(eg, 3), _const(eg, 4)))))
    assert eg.const_of(cid) == 7


def test_const_fold_masks_to_width():
    eg, cid = _saturated(lambda eg: eg.add(
        ENode("add", 16, (_const(eg, 0xFFFF, 16), _const(eg, 1, 16)))))
    assert eg.const_of(cid) == 0


def test_commutativity():
    def build(eg):
        a, b = _sym(eg, "a"), _sym(eg, "b")
        return eg.add(ENode("add", 32, (a, b))), \
            eg.add(ENode("add", 32, (b, a)))
    eg, (ab, ba) = _saturated(build)
    assert eg.find(ab) == eg.find(ba)


def test_associativity():
    def build(eg):
        a, b, c = (_sym(eg, n) for n in "abc")
        ab = eg.add(ENode("add", 32, (a, b)))
        return eg.add(ENode("add", 32, (ab, c))), \
            eg.add(ENode("add", 32, (a, eg.add(ENode("add", 32, (b, c))))))
    eg, (left, right) = _saturated(build)
    assert eg.find(left) == eg.find(right)


def test_add_zero_identity():
    def build(eg):
        x = _sym(eg, "x")
        return x, eg.add(ENode("add", 32, (x, _const(eg, 0))))
    eg, (x, x0) = _saturated(build)
    assert eg.find(x) == eg.find(x0)


def test_mul_zero_absorbs():
    eg, cid = _saturated(lambda eg: eg.add(
        ENode("mul", 32, (_sym(eg, "x"), _const(eg, 0)))))
    assert eg.const_of(cid) == 0


def test_sub_self_is_zero():
    def build(eg):
        x = _sym(eg, "x")
        return eg.add(ENode("sub", 32, (x, x)))
    eg, cid = _saturated(build)
    assert eg.const_of(cid) == 0


def test_mul_pow2_is_shl():
    def build(eg):
        x = _sym(eg, "x")
        return eg.add(ENode("mul", 32, (x, _const(eg, 8)))), \
            eg.add(ENode("shl", 32, (x, _const(eg, 3))))
    eg, (mul, shl) = _saturated(build)
    assert eg.find(mul) == eg.find(shl)


def test_div_pow2_is_shr():
    def build(eg):
        x = _sym(eg, "x")
        return eg.add(ENode("div.u", 32, (x, _const(eg, 4)))), \
            eg.add(ENode("shr.u", 32, (x, _const(eg, 2))))
    eg, (div, shr) = _saturated(build)
    assert eg.find(div) == eg.find(shr)


def test_rem_pow2_is_and():
    def build(eg):
        x = _sym(eg, "x")
        return eg.add(ENode("rem.u", 32, (x, _const(eg, 32)))), \
            eg.add(ENode("and", 32, (x, _const(eg, 31))))
    eg, (rem, mask) = _saturated(build)
    assert eg.find(rem) == eg.find(mask)


def test_mad_fusion_both_directions():
    def build(eg):
        x, y, c = (_sym(eg, n) for n in "xyc")
        mul = eg.add(ENode("mul", 32, (x, y)))
        return eg.add(ENode("add", 32, (mul, c))), \
            eg.add(ENode("mad", 32, (x, y, c)))
    eg, (add, mad) = _saturated(build)
    assert eg.find(add) == eg.find(mad)


def test_float_ops_stay_opaque():
    """Opaque ``op:`` nodes must never merge with anything by rules."""
    def build(eg):
        a, b = _sym(eg, "fa"), _sym(eg, "fb")
        return eg.add(ENode("op:add.f32", 32, (a, b))), \
            eg.add(ENode("op:add.f32", 32, (b, a)))
    eg, (ab, ba) = _saturated(build)
    assert eg.find(ab) != eg.find(ba)   # no float commutativity


# ---------------------------------------------------------------------------
# property: const folding agrees with masked Python arithmetic
# ---------------------------------------------------------------------------

_FOLD_OPS = ["add", "sub", "mul", "and", "or", "xor", "shl", "shr.u",
             "shr.s", "min.u", "max.s"]


def _const_fold_case(seed):
    rng = random.Random(seed)
    width = rng.choice([16, 32, 64])
    op = rng.choice(_FOLD_OPS)
    a = rng.randrange(0, 1 << width)
    b = rng.randrange(0, width if op.startswith("sh") else 1 << width)
    eg = EGraph()
    cid = eg.add(ENode(op, width,
                       (_const(eg, a, width), _const(eg, b, width))))
    saturate_block(eg, default_rules())
    assert eg.const_of(cid) == _mask(_fold(op, width, [a, b]), width)


@pytest.mark.parametrize("seed", range(6))
def test_const_fold_matches_reference(seed):
    _const_fold_case(seed)


@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
@settings(max_examples=60, deadline=None)
def test_property_const_fold(seed):
    _const_fold_case(seed)
