"""Golden byte-identity for the fast-path emulator (PR 6).

The golden file was captured from the pre-fast-path tree; these tests
pin the optimized emulator (pre-decoded micro-ops, COW environments,
cheap interning) to *bit-identical* observables — printed PTX, flow
event sequences, detection pairs — plus direct semantic checks on the
COW structures and the opt-in pruning mode.
"""

from __future__ import annotations

import json

import pytest

from emulator_golden import (
    BRANCHY_PTX,
    GOLDEN_PATH,
    capture_all,
    capture_kernel,
)


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as fh:
        return json.load(fh)


@pytest.fixture(scope="module")
def current():
    # one capture for all parametrized cases; JSON round-trip normalizes
    # tuples/ints exactly the way the golden file was serialized
    return json.loads(json.dumps(capture_all()))


def test_golden_covers_suite(golden):
    assert len(golden) == 20
    assert "branchy" in golden
    assert sum(k.startswith("kernelgen:") for k in golden) == 16


def test_no_new_or_missing_kernels(golden, current):
    assert sorted(current) == sorted(golden)


@pytest.mark.parametrize("which", ["ptx_sha256", "detection", "flows"])
def test_byte_identity(golden, current, which):
    for name in sorted(golden):
        assert current[name][which] == golden[name][which], (
            f"{name}: {which} drifted from the pre-fast-path emulator")


def test_capture_is_deterministic():
    """Per-emulator id wells: two captures in one process are identical
    (module-global counters would leak state between them)."""
    from repro.core.ptx.parser import parse

    kernel = parse(BRANCHY_PTX).kernels[0]
    first = capture_kernel(kernel)
    second = capture_kernel(parse(BRANCHY_PTX).kernels[0])
    assert first == second


# ---------------------------------------------------------------------------
# COW environment semantics
# ---------------------------------------------------------------------------

def test_cow_dict_fork_isolation():
    from repro.core.emulator.machine import _CowDict

    d = _CowDict()
    d["r1"] = 1
    d["r2"] = 2
    child = d.fork()
    child["r1"] = 10          # copy-on-write: parent untouched
    d["r3"] = 3               # and vice versa
    assert d["r1"] == 1 and child["r1"] == 10
    assert "r3" in d and "r3" not in child
    assert child["r2"] == 2   # unwritten keys still shared/visible
    child.pop("r2")
    assert d["r2"] == 2


def test_cow_list_spine_copy_shares_events():
    """The trace COW copies the spine only: event *objects* stay shared
    so in-place invalidation in one flow is visible to its sibling —
    the exact pre-PR shallow-copy (``list(trace)``) semantics."""
    from repro.core.emulator.machine import _CowList

    class Ev:
        def __init__(self):
            self.invalidated = False

    shared = Ev()
    trace = _CowList()
    trace.append(shared)
    child = trace.fork()
    child.append(Ev())        # spine diverges...
    trace.append(Ev())
    assert len(trace) == 2 and len(child) == 2
    assert trace.to_list()[1] is not child.to_list()[1]
    shared.invalidated = True  # ...but prefix events stay one object
    assert child.to_list()[0].invalidated
    assert trace.to_list()[0] is child.to_list()[0]


def test_branchy_flow_forks_are_independent():
    """End-to-end COW stress: the fork-heavy kernel's flows must not
    bleed register state or trace events into each other."""
    from repro.core.emulator.machine import emulate
    from repro.core.ptx.parser import parse

    kernel = parse(BRANCHY_PTX).kernels[0]
    flows = emulate(kernel)
    assert len(flows) >= 3           # early-exit, left, right at minimum
    assert len({fr.flow_id for fr in flows}) == len(flows)
    # each trace is a plain list the caller owns
    sigs = {fr.flow_id: [(type(e).__name__, e.stmt_uid, e.order)
                         for e in fr.trace] for fr in flows}
    assert len(set(map(tuple, sigs.values()))) > 1


# ---------------------------------------------------------------------------
# detection-aware pruning (on by default) keeps observables identical here
# ---------------------------------------------------------------------------

def test_prune_flows_preserves_ptx_and_pairs():
    from repro.core.driver import Compiler
    from repro.core.frontend.kernelgen import all_benches
    from repro.core.frontend.stencil import lower_to_ptx
    from repro.core.ptx import Module

    module = Module(kernels=[lower_to_ptx(b.program)
                             for b in all_benches().values()])
    with Compiler(jobs=0, prune_flows=False) as base, \
            Compiler(jobs=0, prune_flows=True) as pruned:
        r0 = base.compile(module, cache=None)
        r1 = pruned.compile(module, cache=None)
    assert r1.ptx == r0.ptx
    for a, b in zip(r0.reports, r1.reports):
        assert a.name == b.name
        pa = sorted((p.dst_uid, p.src_uid, p.delta) for p in a.detection.pairs)
        pb = sorted((p.dst_uid, p.src_uid, p.delta) for p in b.detection.pairs)
        assert pa == pb, f"{a.name}: pruning changed detection"
        assert a.detection.n_flows == b.detection.n_flows


# branch fork order: the *taken* flow continues in the main loop and
# the fallthrough is the forked child, so pruning fires when the
# fallthrough path cannot reach memory — here it is a bare ``ret``
PRUNABLE_PTX = """
.visible .entry prunable(
    .param .u64 a
)
{
    .reg .pred %p1;
    .reg .u32 %r<4>;
    .reg .u64 %rd<4>;

    ld.param.u64 %rd1, [a];
    mov.u32 %r1, %tid.x;
    setp.lt.u32 %p1, %r1, 16;
    @%p1 bra MEM;
    ret;
MEM:
    mul.wide.u32 %rd2, %r1, 4;
    add.u64 %rd3, %rd1, %rd2;
    ld.global.u32 %r2, [%rd3];
    st.global.u32 [%rd3], %r2;
    ret;
}
"""


def test_pruned_stub_flows_skipped_by_detection():
    """A pruned child appears as a stub FlowResult that detection
    ignores, keeping ``n_flows`` stable."""
    from repro.core.emulator.machine import emulate
    from repro.core.ptx.parser import parse
    from repro.core.synthesis.detect import detect

    kernel = parse(PRUNABLE_PTX).kernels[0]
    base = emulate(kernel, prune_flows=False)
    counters: dict = {}
    flows = emulate(kernel, counters=counters, prune_flows=True)
    pruned = [fr for fr in flows if fr.terminated == "pruned"]
    assert counters["pruned_flows"] == len(pruned) == 1
    assert len(flows) == len(base)        # stub keeps the flow count
    d_base = detect(kernel, base)
    d_pruned = detect(kernel, flows)
    assert d_pruned.n_flows == d_base.n_flows
    assert d_pruned.n_loads == d_base.n_loads
    assert [(p.dst_uid, p.src_uid, p.delta) for p in d_pruned.pairs] \
        == [(p.dst_uid, p.src_uid, p.delta) for p in d_base.pairs]
