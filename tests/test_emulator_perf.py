"""PR 6 observability surface: emulator counters, budget options,
truncation diagnostics, and the benchmark snapshot writer/checker."""

from __future__ import annotations

import json

import pytest

from emulator_golden import BRANCHY_PTX

from repro.core.driver import Compiler, Severity
from repro.core.emulator.machine import SymbolicEmulator, emulate
from repro.core.ptx.parser import parse


@pytest.fixture()
def branchy():
    return parse(BRANCHY_PTX).kernels[0]


# ---------------------------------------------------------------------------
# counters
# ---------------------------------------------------------------------------

def test_counters_populated(branchy):
    emu = SymbolicEmulator(branchy)
    flows = emu.run()
    c = emu.counters
    assert c["steps"] > 0
    assert c["flows"] == len(flows)
    assert c["forks"] >= 2                  # two data-dependent branches
    assert c["backedge_exits"] >= 1         # the LOOP back-edge
    assert c["terms_interned"] >= 0
    assert c["truncated_steps"] == 0 and c["truncated_forks"] == 0


def test_emulate_counters_out_param_accumulates(branchy):
    acc: dict = {}
    emulate(branchy, counters=acc)
    first_steps = acc["steps"]
    emulate(branchy, counters=acc)          # second run adds, not replaces
    assert acc["steps"] == 2 * first_steps


def test_counters_reach_compile_result(branchy):
    from repro.core.ptx.printer import print_kernel

    with Compiler(jobs=0) as cc:
        result = cc.compile(print_kernel(branchy), cache=None)
    c = result.emulator_counters
    assert c["steps"] > 0 and c["flows"] >= 3
    # per-report too, and through the JSON wire format
    assert result.reports[0].counters == c
    wire = json.loads(json.dumps(result.to_json_dict()))
    from repro.core.driver import CompileResult
    back = CompileResult.from_json_dict(wire)
    assert back.emulator_counters == c


def test_per_emulator_ids_do_not_leak(branchy):
    """Two emulators over the same kernel allocate identical flow/UF
    ids — nothing is module-global anymore."""
    a = SymbolicEmulator(branchy)
    fa = a.run()
    b = SymbolicEmulator(branchy)
    fb = b.run()
    assert [fr.flow_id for fr in fa] == [fr.flow_id for fr in fb]
    assert min(fr.flow_id for fr in fa) == 0
    assert a.counters == b.counters


# ---------------------------------------------------------------------------
# budgets + truncation diagnostics
# ---------------------------------------------------------------------------

def test_max_steps_truncates_with_warning(branchy):
    from repro.core.ptx.printer import print_kernel

    with Compiler(jobs=0, max_steps=5) as cc:
        result = cc.compile(print_kernel(branchy), cache=None)
    assert result.emulator_counters["truncated_steps"] >= 1
    diags = [d for d in result.diagnostics
             if d.source == "emulate-flows" and "max_steps=5" in d.message]
    assert diags and diags[0].severity == Severity.WARNING


def test_max_flows_drops_forks_with_warning(branchy):
    from repro.core.ptx.printer import print_kernel

    with Compiler(jobs=0, max_flows=1) as cc:
        result = cc.compile(print_kernel(branchy), cache=None)
    assert result.emulator_counters["truncated_forks"] >= 1
    # budget bounds the pending population; unbounded branchy yields >= 3
    assert result.emulator_counters["flows"] <= 2
    diags = [d for d in result.diagnostics
             if d.source == "emulate-flows" and "max_flows=1" in d.message]
    assert diags and diags[0].severity == Severity.WARNING


def test_default_budgets_do_not_warn(branchy):
    from repro.core.ptx.printer import print_kernel

    with Compiler(jobs=0) as cc:
        result = cc.compile(print_kernel(branchy), cache=None)
    assert not [d for d in result.diagnostics
                if "emulation truncated" in d.message]


def test_budgets_are_part_of_cache_token():
    from repro.core.passes.context import PipelineConfig

    a = PipelineConfig().cache_token
    b = PipelineConfig(max_flows=7).cache_token
    c = PipelineConfig(prune_flows=False).cache_token
    assert len({a, b, c}) == 3


# ---------------------------------------------------------------------------
# snapshot writer / checker
# ---------------------------------------------------------------------------

def _mini_snapshot(wall=1.0, calib=0.1, steps=100):
    return {
        "schema": "repro-bench-snapshot",
        "schema_version": 1,
        "machine_calib_s": calib,
        "e1_cold": {
            "wall_s": wall, "mid_end_s": wall * 0.8,
            "emulate_s": wall * 0.3, "detect_s": wall * 0.1,
            "n_kernels": 16, "n_shuffles": 35,
            "counters": {"steps": steps, "forks": 39},
        },
        "e1_warm": {"wall_s": wall * 0.5,
                    "cache_hits": 16, "cache_misses": 16,
                    "cache_hit_rate": 0.5},
    }


def test_check_passes_on_identical():
    from benchmarks.snapshot import check
    assert check(_mini_snapshot(), _mini_snapshot()) == []


def test_check_counters_exact():
    from benchmarks.snapshot import check
    fails = check(_mini_snapshot(steps=101), _mini_snapshot(steps=100))
    assert any("counters.steps" in f for f in fails)


def test_check_time_budget_scales_with_calibration():
    from benchmarks.snapshot import check
    # 1.5x slower wall time fails at 25% tolerance...
    assert any("wall_s" in f for f in
               check(_mini_snapshot(wall=1.5), _mini_snapshot(wall=1.0)))
    # ...unless the machine itself measures 1.5x slower
    assert check(_mini_snapshot(wall=1.5, calib=0.15),
                 _mini_snapshot(wall=1.0, calib=0.1)) == []
    # and a custom tolerance widens the budget
    assert check(_mini_snapshot(wall=1.5), _mini_snapshot(wall=1.0),
                 time_tolerance=0.6) == []


def test_check_schema_mismatch_fails_fast():
    from benchmarks.snapshot import check
    bad = _mini_snapshot()
    bad["schema"] = "something-else"
    fails = check(bad, _mini_snapshot())
    assert len(fails) == 1 and "schema" in fails[0]


def test_committed_baseline_is_well_formed():
    """BENCH_PR8.json in the repo root must parse, carry the schema
    stamp, and self-check cleanly (timings identical to themselves)."""
    import os
    from benchmarks.snapshot import SCHEMA, check, load

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_PR8.json")
    snap = load(path)
    assert snap["schema"] == SCHEMA
    assert snap["e1_cold"]["n_kernels"] == 16
    assert snap["e1_cold"]["counters"]["steps"] > 0
    assert snap["e1_warm"]["cache_hits"] == 16
    sat = snap["e1_saturate"]
    assert sat["soundness_failures"] == 0
    assert sat["n_improved"] >= 3
    assert sat["counters"]["sat_cycle_delta_milli"] > 0
    lint = snap["e1_lint"]
    assert lint["n_findings"] == 0
    assert lint["lint_s"] < 0.10 * snap["e1_cold"]["wall_s"]
    assert check(snap, snap) == []


def test_snapshot_write_load_roundtrip(tmp_path):
    from benchmarks.snapshot import load, write
    snap = _mini_snapshot()
    p = str(tmp_path / "snap.json")
    write(snap, p)
    assert load(p) == snap
