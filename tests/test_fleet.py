"""Fleet serving subsystem tests.

Covers the pieces in isolation (histogram, bounded queue, coalescer,
wire form, cache tier server) and the integrated contracts the fleet
smoke asserts at scale: the coalescer hammer (K concurrent identical
requests -> exactly one compile, K byte-identical responses), the
remote cache tier across two replicas (zero re-emulations on the warm
one), deterministic backpressure (503 + Retry-After), and per-request
deadlines (504)."""

import dataclasses
import json
import threading
import time

import pytest

from repro.core.driver import Compiler
from repro.core.frontend.kernelgen import get_bench
from repro.core.frontend.stencil import lower_to_ptx
from repro.core.passes.cache import CompileCache
from repro.core.ptx import print_kernel
from repro.core.ptx.parser import parse
from repro.launch.fleet import (
    CacheTierServer,
    FleetServer,
    Flight,
    FlightTimeout,
    Job,
    JobQueue,
    LatencyHistogram,
    QueueClosed,
    QueueFull,
    RemoteCache,
    RequestCoalescer,
)
from repro.launch.fleet.remote_cache import decode_entry, encode_entry
from repro.launch.ptx_service import BackpressureError, PtxServiceClient


def _vecadd_kernel():
    return lower_to_ptx(get_bench("vecadd").program)


@dataclasses.dataclass
class FakeReport:
    name: str
    cached: bool = False


def _poll(predicate, timeout=30.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.01)
    pytest.fail(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# latency histogram
# ---------------------------------------------------------------------------

def test_histogram_empty_and_shape():
    h = LatencyHistogram()
    d = h.to_dict()
    assert d["count"] == 0 and d["p99_s"] == 0.0 and d["max_s"] == 0.0


def test_histogram_percentiles_bound_the_samples():
    h = LatencyHistogram()
    for ms in (1, 1, 1, 1, 1, 1, 1, 1, 1, 100):
        h.record(ms / 1000.0)
    # p50 must bucket near 1ms, p99 near the 100ms outlier
    assert h.percentile(50) <= 0.01
    assert h.percentile(99) >= 0.1
    assert h.to_dict()["max_s"] == pytest.approx(0.1)
    h.record(-5.0)                       # clock weirdness: clamps, no throw
    assert h.count == 11


# ---------------------------------------------------------------------------
# bounded queue
# ---------------------------------------------------------------------------

def _job(deadline=None):
    return Job(prepared=None, flight=None, deadline=deadline)


def test_queue_fifo_backpressure_and_counters():
    q = JobQueue(capacity=2)
    a, b = _job(), _job()
    q.put(a)
    q.put(b)
    with pytest.raises(QueueFull):
        q.put(_job())
    batch = q.take_batch(max_items=8, window_s=0.0)
    assert batch[0] is a and batch[1] is b        # FIFO, burst collected
    c = q.counters()
    assert c["enqueued"] == 2 and c["rejected"] == 1
    assert c["max_depth"] == 2 and c["depth"] == 0


def test_queue_close_refuses_then_drains():
    q = JobQueue(capacity=4)
    q.put(_job())
    q.close()
    with pytest.raises(QueueClosed):
        q.put(_job())
    assert len(q.take_batch()) == 1               # drain continues...
    assert q.take_batch() is None                 # ...then signals exit


def test_queue_batch_window_collects_late_arrivals():
    q = JobQueue(capacity=8)
    q.put(_job())
    got = []
    t = threading.Thread(
        target=lambda: got.append(q.take_batch(max_items=4, window_s=2.0)))
    t.start()
    time.sleep(0.05)
    q.put(_job())
    q.put(_job())
    q.close()                   # close also ends the lingering window
    t.join(timeout=10)
    assert not t.is_alive() and len(got[0]) >= 1


def test_job_deadline():
    now = time.monotonic()
    assert not _job().expired()                   # no deadline: immortal
    assert _job(deadline=now - 1).expired()
    assert not _job(deadline=now + 60).expired()


# ---------------------------------------------------------------------------
# coalescer
# ---------------------------------------------------------------------------

def test_coalescer_join_resolve_and_window_close():
    co = RequestCoalescer()
    f1, created = co.join("k")
    assert created and f1.n_waiters == 1
    f2, created2 = co.join("k")
    assert f2 is f1 and not created2 and f1.n_waiters == 2
    co.finish(f1)                                 # window closed...
    f3, created3 = co.join("k")
    assert created3 and f3 is not f1              # ...fresh flight after
    f1.resolve({"x": 1})
    assert f1.wait(1.0) == {"x": 1}
    c = co.counters()
    assert c["flights"] == 2 and c["joined"] == 1 and c["open"] == 1


def test_flight_failure_reaches_every_waiter():
    co = RequestCoalescer()
    f, _ = co.join("k")
    co.join("k")
    results = []
    threads = [threading.Thread(
        target=lambda: results.append(pytest.raises(ValueError, f.wait, 5.0)))
        for _ in range(2)]
    for t in threads:
        t.start()
    co.abandon(f, ValueError("boom"))
    for t in threads:
        t.join(timeout=10)
    assert len(results) == 2
    assert co.counters()["abandoned"] == 1 and co.counters()["open"] == 0


def test_flight_wait_timeout():
    f = Flight("k")
    with pytest.raises(FlightTimeout):
        f.wait(0.01)


# ---------------------------------------------------------------------------
# wire form + cache tier server
# ---------------------------------------------------------------------------

def test_entry_wire_roundtrip():
    kernel = _vecadd_kernel()
    blob = encode_entry("some-key", kernel, FakeReport("vecadd", cached=True))
    loaded = decode_entry(blob)
    assert loaded is not None
    k2, r2 = loaded
    assert print_kernel(k2) == print_kernel(kernel)
    assert r2.name == "vecadd"
    assert r2.cached is False, "wire form stores the pristine report"


def test_decode_rejects_corruption_and_schema_drift():
    kernel = _vecadd_kernel()
    blob = encode_entry("k", kernel, FakeReport("vecadd"))
    assert decode_entry(b"not json at all") is None
    drifted = json.loads(blob)
    drifted["schema"] = -1
    assert decode_entry(json.dumps(drifted).encode()) is None
    corrupt = json.loads(blob)
    corrupt["report_b64"] = "AAAA"
    assert decode_entry(json.dumps(corrupt).encode()) is None


def test_cache_tier_server_lru_by_bytes():
    srv = CacheTierServer(max_bytes=100)
    srv.put("a" * 64, b"x" * 60)
    srv.put("b" * 64, b"y" * 60)                  # evicts a (120 > 100)
    assert srv.get("a" * 64) is None
    assert srv.get("b" * 64) == b"y" * 60
    st = srv.stats_payload()
    assert st["evictions"] == 1 and st["entries"] == 1
    assert st["gets"] == 2 and st["hits"] == 1


def test_cache_tier_disk_spill_survives_restart(tmp_path):
    spill = str(tmp_path / "tier")
    kernel = _vecadd_kernel()
    with CacheTierServer(cache_dir=spill) as tier:
        tier.start()
        rc = RemoteCache(tier.url)
        rc.store("warm", kernel, FakeReport("vecadd"))
        st = tier.stats_payload()
        assert st["disk_puts"] == 1 and st["disk_entries"] == 1
        assert st["disk_errors"] == 0
    # a fresh process over the same directory answers from disk
    with CacheTierServer(cache_dir=spill) as tier:
        tier.start()
        rc = RemoteCache(tier.url)
        loaded = rc.load("warm")
        assert loaded is not None
        assert print_kernel(loaded[0]) == print_kernel(kernel)
        st = tier.stats_payload()
        assert st["disk_hits"] == 1 and st["cache_dir"] == spill
        assert st["entries"] == 1                 # promoted to hot set
        assert rc.load("warm") is not None        # second hit: memory
        assert tier.stats_payload()["disk_hits"] == 1


def test_cache_tier_eviction_keeps_disk_superset(tmp_path):
    spill = str(tmp_path / "tier")
    srv = CacheTierServer(max_bytes=100, cache_dir=spill)
    srv.put("a" * 64, b"x" * 60)
    srv.put("b" * 64, b"y" * 60)                  # evicts a from memory
    assert srv.stats_payload()["evictions"] == 1
    assert srv.get("a" * 64) == b"x" * 60         # ...but disk still has it
    assert srv.stats_payload()["disk_hits"] == 1


def test_remote_cache_http_roundtrip_and_counters():
    kernel = _vecadd_kernel()
    with CacheTierServer() as tier:
        tier.start()
        rc = RemoteCache(tier.url)
        assert rc.healthz()
        assert rc.load("k1") is None              # cold: miss
        rc.store("k1", kernel, FakeReport("vecadd"))
        loaded = rc.load("k1")
        assert loaded is not None
        assert print_kernel(loaded[0]) == print_kernel(kernel)
        assert rc.counters == {"gets": 2, "hits": 1, "misses": 1,
                               "puts": 1, "errors": 0}
        assert rc.server_stats()["entries"] == 1


def test_remote_cache_dead_server_degrades_to_miss():
    rc = RemoteCache("http://127.0.0.1:9", timeout=0.2)  # nothing there
    assert rc.load("k") is None
    assert rc.store("k", _vecadd_kernel(), FakeReport("v")) == 0
    assert rc.healthz() is False
    c = rc.counters
    assert c["errors"] == 2 and c["misses"] == 1 and c["puts"] == 0


def test_remote_cache_url_validation():
    with pytest.raises(ValueError, match="http"):
        RemoteCache("https://example.com:443")
    with pytest.raises(ValueError, match="host and port"):
        RemoteCache("http://nohost")
    assert RemoteCache("127.0.0.1:8790").port == 8790  # bare host:port ok


# ---------------------------------------------------------------------------
# CompileCache remote tier (no HTTP: a dict-backed fake)
# ---------------------------------------------------------------------------

class DictRemote:
    """In-memory stand-in with the tier interface."""

    def __init__(self):
        self.blobs = {}

    def store(self, key, kernel, report):
        self.blobs[key] = encode_entry(key, kernel, report)
        return 0

    def load(self, key):
        blob = self.blobs.get(key)
        return None if blob is None else decode_entry(blob)


def test_compile_cache_remote_tier_write_through_and_warm_hit():
    remote = DictRemote()
    with Compiler(jobs=1, cache=CompileCache(remote=remote)) as c1:
        c1.compile(get_bench("vecadd"))
    assert len(remote.blobs) == 1, "put must write through to the remote"

    with Compiler(jobs=1, cache=CompileCache(remote=remote)) as c2:
        res = c2.compile(get_bench("vecadd"))
        stats = c2.cache_stats
        assert stats.remote_hits == 1 and stats.misses == 1
        assert res.reports[0].cached
        assert "emulate-flows" not in c2.pass_times, \
            "a remote hit must skip symbolic emulation entirely"


# ---------------------------------------------------------------------------
# FleetServer integration
# ---------------------------------------------------------------------------

def _gate_compiles(server):
    """Replace the server compiler's ``submit_prepared`` with a gated
    wrapper: workers block until ``release.set()``, and every submit is
    recorded.  Makes coalescing/backpressure windows deterministic."""
    release = threading.Event()
    calls = []
    orig = server.compiler.submit_prepared

    def gated(prepared):
        calls.append(prepared.key)
        assert release.wait(60), "test gate never released"
        return orig(prepared)

    server.compiler.submit_prepared = gated
    return release, calls


def test_coalescer_hammer_one_compile_k_identical_responses():
    k = 6
    with FleetServer(workers=1, jobs=2) as srv:
        srv.start()
        release, calls = _gate_compiles(srv)
        client = PtxServiceClient(srv.host, srv.port)
        payloads, errors = [], []
        lock = threading.Lock()

        def hammer():
            try:
                resp = client.compile(bench="vecadd")
                with lock:
                    payloads.append(json.dumps(resp, sort_keys=True))
            except BaseException as e:  # noqa: BLE001
                with lock:
                    errors.append(e)

        threads = [threading.Thread(target=hammer) for _ in range(k)]
        for t in threads:
            t.start()
        try:
            # all K requests are in the building before any compile runs
            _poll(lambda: srv.coalescer.counters()["joined"] == k - 1,
                  what=f"{k - 1} joins (got {srv.coalescer.counters()})")
            assert len(calls) <= 1
        finally:
            release.set()
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors[0]
        assert len(calls) == 1, "exactly one compile for K identical reqs"
        assert len(set(payloads)) == 1 and len(payloads) == k, \
            "coalesced responses must be byte-identical"
        st = srv.stats_payload()
        assert st["cache"]["misses"] == 1
        assert st["fleet"]["coalesce"]["joined"] == k - 1
        assert st["fleet"]["latency"]["total"]["count"] == k


def test_two_replicas_share_the_remote_tier():
    with CacheTierServer() as tier:
        tier.start()
        with FleetServer(remote_cache=tier.url, workers=2, jobs=2) as a:
            a.start()
            ca = PtxServiceClient(a.host, a.port)
            cold = ca.compile(bench="jacobi")
        with FleetServer(remote_cache=tier.url, workers=2, jobs=2) as b:
            b.start()
            cb = PtxServiceClient(b.host, b.port)
            warm = cb.compile(bench="jacobi")
            st = cb.stats()
        assert warm["ptx"] == cold["ptx"], \
            "the network tier must serve byte-identical PTX"
        assert st["cache"]["remote_hits"] == 1
        assert st["pass_times"].get("emulate-flows", 0.0) == 0.0, \
            "warm replica must not re-emulate"
        assert st["remote"]["hits"] == 1 and st["remote"]["url"] == tier.url
        assert tier.stats_payload()["hits"] == 1


def test_backpressure_503_with_retry_after_hint():
    with FleetServer(workers=1, jobs=1, queue_capacity=1,
                     batch_max=1) as srv:
        srv.start()
        release, calls = _gate_compiles(srv)
        client = PtxServiceClient(srv.host, srv.port)
        done = []

        def post(name):
            done.append(client.compile(bench=name))

        t1 = threading.Thread(target=post, args=("vecadd",))
        t1.start()
        _poll(lambda: len(calls) == 1, what="worker holding job 1")
        t2 = threading.Thread(target=post, args=("jacobi",))
        t2.start()
        _poll(lambda: srv.queue.depth == 1, what="job 2 queued")
        try:
            with pytest.raises(BackpressureError) as exc:
                client.compile(bench="laplacian")    # queue full: 503
            assert exc.value.retry_after >= 1
            assert client.counters["backpressure"] == 1
            assert srv.queue.counters()["rejected"] == 1
        finally:
            release.set()
        t1.join(timeout=60)
        t2.join(timeout=60)
        assert len(done) == 2, "obeying the 503 must not lose the others"


def test_deadline_times_out_both_in_flight_and_in_queue():
    with FleetServer(workers=1, jobs=1, queue_capacity=4, batch_max=1,
                     deadline_s=0.3) as srv:
        srv.start()
        release, calls = _gate_compiles(srv)
        client = PtxServiceClient(srv.host, srv.port)
        errors = []

        def post(name):
            try:
                client.compile(bench=name)
            except RuntimeError as e:
                errors.append(str(e))

        t1 = threading.Thread(target=post, args=("vecadd",))
        t1.start()
        _poll(lambda: len(calls) == 1, what="worker holding job 1")
        t2 = threading.Thread(target=post, args=("jacobi",))
        t2.start()
        t1.join(timeout=60)
        t2.join(timeout=60)
        # both clients saw 504: one timed out mid-compile, one in queue
        assert len(errors) == 2 and all("504" in e for e in errors), errors
        release.set()
        # the worker must skip the expired queued job, not compile it
        _poll(lambda: srv.queue.counters()["expired"] == 1,
              what="expired job skipped by the worker")


def test_fleet_close_unstarted_and_stats_shape():
    srv = FleetServer(workers=2)
    st = srv.stats_payload()
    assert {"workers", "queue", "coalesce", "latency"} <= set(st["fleet"])
    assert st["fleet"]["workers"] == 2
    srv.close()                       # must not hang on idle workers
    assert srv.queue.closed
