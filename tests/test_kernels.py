"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles,
plus the PTXASW <-> kernel-plan consistency properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # degrade: property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core.frontend.kernelgen import all_benches, get_bench
from repro.core.frontend.pallas_lower import synthesize_tpu
from repro.kernels.conv1d import causal_conv1d, hbm_bytes
from repro.kernels.conv1d import ref as conv_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.stencil import make_plan, reference, stencil_apply

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# stencil kernel
# ---------------------------------------------------------------------------

STENCIL_BENCHES = ["jacobi", "gaussblur", "laplacian", "wave13pt",
                   "whispering", "gradient", "divergence", "gameoflife",
                   "lapgsrb", "uxx1", "tricubic", "sincos", "vecadd"]


@pytest.mark.parametrize("name", STENCIL_BENCHES)
@pytest.mark.parametrize("mode", ["naive", "paper", "tile"])
def test_stencil_matches_oracle(name, mode):
    b = get_bench(name)
    prog = b.program
    nd = prog.ndim
    shape = {1: (300,), 2: (20, 140), 3: (6, 20, 140)}[nd]
    arrays = {a: jnp.asarray(RNG.standard_normal(shape[-d:]), jnp.float32)
              for a, d in prog.arrays.items() if a != prog.out.array}
    scalars = {s: float(RNG.uniform(0.1, 1.0)) for s in prog.scalars}
    ref = reference(prog, arrays, scalars)
    out = stencil_apply(prog, arrays, scalars, mode=mode,
                        block={1: (64,), 2: (8, 32), 3: (1, 8, 32)}[nd])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", sorted(all_benches(include_apps=True)))
def test_detection_plan_consistency(name):
    """The symbolic emulator's shuffle count must equal the geometric
    row-coverable tap count of the Pallas 'paper' plan (DESIGN.md §2)."""
    b = all_benches(include_apps=True)[name]
    plan = synthesize_tpu(b.program, max_delta=b.max_delta)
    assert plan.consistent


def test_traffic_ordering():
    """tile <= paper <= naive bytes for every stencil."""
    for name in ("jacobi", "gaussblur", "tricubic", "lapgsrb"):
        prog = get_bench(name).program
        block = {2: (8, 128), 3: (1, 8, 128)}[prog.ndim]
        naive = make_plan(prog, "naive").bytes_per_block(block)
        paper = make_plan(prog, "paper").bytes_per_block(block)
        tile = make_plan(prog, "tile").bytes_per_block(block)
        assert tile <= paper <= naive


# ---------------------------------------------------------------------------
# conv1d (Mamba-2 integration of the paper's technique)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 64, 32, 4), (1, 100, 48, 4),
                                   (3, 33, 17, 3), (2, 256, 96, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["naive", "shuffle"])
def test_conv1d_matches_oracle(shape, dtype, mode):
    B, L, C, W = shape
    x = jnp.asarray(RNG.standard_normal((B, L, C)), dtype)
    w = jnp.asarray(RNG.standard_normal((W, C)), dtype)
    b = jnp.asarray(RNG.standard_normal((C,)), dtype)
    ref = conv_ref.causal_conv1d(x, w, b)
    out = causal_conv1d(x, w, b, mode=mode, block_seq=32, block_ch=16)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_conv1d_traffic_reduction():
    r = hbm_bytes(4096, 4096, 4, "naive") / hbm_bytes(4096, 4096, 4, "shuffle")
    assert r > 3.5   # W=4 taps -> ~4x fewer HBM reads


def test_ptxasw_finds_conv_deltas():
    """The paper's analysis applied to the Mamba conv pattern: a width-4
    causal 1D stencil yields 3 shuffles with deltas {1,2,3}."""
    from repro.core.frontend.stencil import Array, I, Program, lower_to_ptx
    from repro.core.synthesis.pipeline import ptxasw_kernel
    x = Array("x")
    expr = (0.1 * x[I(-3)] + 0.2 * x[I(-2)] + 0.3 * x[I(-1)] + 0.4 * x[I(0)])
    prog = Program(name="conv1d", ndim=1, out=Array("y")[I()], expr=expr)
    _, rep = ptxasw_kernel(lower_to_ptx(prog))
    deltas = sorted(p.delta for p in rep.detection.pairs)
    assert deltas == [1, 2, 3]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 64, 64, 4, 2, 16, True),
                                   (1, 100, 100, 4, 4, 8, True),
                                   (2, 64, 64, 8, 2, 16, False),
                                   (1, 33, 33, 2, 1, 32, True),
                                   (2, 48, 96, 4, 1, 16, True)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(shape, dtype):
    B, Sq, Sk, H, KV, Dh, causal = shape
    q = jnp.asarray(RNG.standard_normal((B, Sq, H, Dh)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Sk, KV, Dh)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Sk, KV, Dh)), dtype)
    ref = attention_ref(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    tol = 2e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(17, 80), st.integers(1, 2),
       st.sampled_from([8, 16]))
def test_flash_attention_property(B, S, KV, Dh):
    H = KV * 2
    q = jnp.asarray(RNG.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KV, Dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KV, Dh)), jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
