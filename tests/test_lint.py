"""Static PTX semantic analyzer (PR 8): unit + integration tests.

Covers the four analyses (uniformity, synchronization, shared-memory
races, def-use), the adversarial corpus in ``tests/lint_corpus/``, the
clean-corpora property, the uniformity gate inside ``select-shuffles``
and egraph ``extract``, diagnostic deduplication, the JSON wire form,
the CLI, and the ``POST /lint`` service endpoint.
"""

import glob
import json
import os

import pytest

from emulator_golden import BRANCHY_PTX

CORPUS_DIR = os.path.join(os.path.dirname(__file__), "lint_corpus")


def _corpus(name: str) -> str:
    with open(os.path.join(CORPUS_DIR, name), "r", encoding="utf-8") as fh:
        return fh.read()


def _lint(text: str):
    from repro.core.analysis.lint import lint_source
    return lint_source(text)


# ---------------------------------------------------------------------------
# adversarial corpus: every planted bug detected, exact code/severity/uid
# ---------------------------------------------------------------------------

CORPUS_EXPECTATIONS = {
    # file -> set of (code, severity name, uid)
    "div_shfl.ptx": {("divergent-shfl", "ERROR", 7)},
    "bar_deadlock.ptx": {("divergent-barrier", "ERROR", 6)},
    "shared_race.ptx": {("shared-race", "WARNING", 6)},
    "shared_synced.ptx": set(),
    "undef_use.ptx": {("undef-use", "ERROR", 2)},
    "width_mismatch.ptx": {("width-mismatch", "WARNING", 2)},
    # the relational membermask prover (PR 10)
    "mask_reg_full.ptx": {("membermask-proven", "NOTE", 6)},
    "mask_wrong.ptx": {("membermask-noncovering", "ERROR", 5)},
    "mask_guarded_covering.ptx": {("membermask-proven", "NOTE", 7)},
    "mask_loop_carried.ptx": {("membermask-unprovable", "WARNING", 8)},
}


def test_corpus_is_complete():
    files = {os.path.basename(p)
             for p in glob.glob(os.path.join(CORPUS_DIR, "*.ptx"))}
    assert files == set(CORPUS_EXPECTATIONS)


@pytest.mark.parametrize("fname", sorted(CORPUS_EXPECTATIONS))
def test_corpus_kernel_findings(fname):
    findings = _lint(_corpus(fname))
    got = {(f.code, f.severity.name, f.uid) for f in findings}
    assert got == CORPUS_EXPECTATIONS[fname], findings


def test_race_finding_names_the_store():
    [f] = _lint(_corpus("shared_race.ptx"))
    assert "uid:3" in f.message      # the racing store's anchor
    assert f.detail == "st:3"        # ...and in the dedup key
    assert f.location == "uid:6:st:3"   # reported at the load


def test_finding_str_and_dict_roundtrip():
    from repro.core.analysis.findings import Finding
    [f] = _lint(_corpus("undef_use.ptx"))
    assert str(f) == ("undef_use:2: error [undef-use] register %r4 is "
                      "read but never defined on any path from the "
                      "kernel entry")
    assert Finding.from_dict(f.to_dict()) == f


# ---------------------------------------------------------------------------
# clean corpora: KernelGen suite + applications + golden branchy
# ---------------------------------------------------------------------------

def test_builtin_corpora_lint_clean():
    """The 19 lowered bench kernels carry zero findings of any level."""
    from repro.core.analysis.lint import corpus_kernels, lint_kernel
    kernels = corpus_kernels("all")
    assert len(kernels) == 19
    for name, kernel in kernels:
        findings = lint_kernel(kernel, kernel_name=name)
        assert findings == [], (name, findings)


def test_branchy_lints_note_only():
    """The golden stress kernel: exactly one NOTE (an intentional
    float-load-into-int-register reinterpretation), nothing worse."""
    from repro.core.driver.result import Severity
    findings = _lint(BRANCHY_PTX)
    assert [(f.code, f.uid) for f in findings] == [("type-class", 8)]
    assert findings[0].severity == Severity.NOTE


# ---------------------------------------------------------------------------
# uniformity analysis facts on the branchy kernel
# ---------------------------------------------------------------------------

def test_branchy_uniformity_levels():
    from repro.core.analysis.uniformity import (
        EXIT_GUARD, JOIN, UNIFORM)
    from repro.core.passes.context import KernelContext, PipelineConfig
    from repro.core.ptx.parser import parse

    kernel = parse(BRANCHY_PTX).kernels[0]
    ctx = KernelContext(kernel, PipelineConfig())
    info = ctx.get("uniformity")
    assert info.block_level == [UNIFORM, EXIT_GUARD, JOIN, JOIN, JOIN,
                                EXIT_GUARD, UNIFORM]
    # @%p1 bra DONE guards a pure exit; @%p2 bra LEFT joins observable
    # work; @%p3 bra LOOP's predicate was re-defined from uniform
    # sources (setp.lt.u32 %p3, %r5, 4 — %r5 is not tid-derived)
    assert info.branch_class == {5: EXIT_GUARD, 10: JOIN, 20: UNIFORM}


def test_reach_seeds_labels_and_memory():
    """`prune_flows` soundness: a pc that can still reach a Label must
    stay unpruned (block-entry memoization observes it) even when no
    memory op is reachable."""
    from repro.core.analysis.reach import reach_flags
    from repro.core.emulator.decode import decode_kernel
    from repro.core.ptx.parser import parse

    src = """
.visible .entry reachy(.param .u64 a)
{
    .reg .pred %p1;
    .reg .u32 %r<4>;
    .reg .u64 %rd<4>;
    ld.param.u64 %rd1, [a];
    mov.u32 %r1, %tid.x;
    setp.lt.u32 %p1, %r1, 16;
    @%p1 bra MEM;
    ret;
MEM:
    st.global.u32 [%rd1], %r1;
EMPTY:
    ret;
}
"""
    kernel = parse(src).kernels[0]
    kernel.renumber()
    flags = reach_flags(decode_kernel(kernel))
    # uid 4 is the bare fallthrough ret: nothing reachable -> prunable
    assert flags[4] is False
    # labels themselves seed reachability (memoization-relevant), even
    # the trailing EMPTY one whose only successor is ret
    from repro.core.emulator.decode import K_LABEL
    label_uids = [d.uid for d in decode_kernel(kernel)
                  if d.kind == K_LABEL]
    assert len(label_uids) == 2
    for uid in label_uids:
        assert flags[uid] is True
    # everything from the entry is live
    assert flags[0] is True


def test_prune_default_on_and_in_cache_token():
    from repro.core.passes.context import PipelineConfig
    assert PipelineConfig().prune_flows is True
    assert PipelineConfig().cache_token \
        != PipelineConfig(prune_flows=False).cache_token
    assert PipelineConfig().cache_token \
        != PipelineConfig(lint="warn").cache_token


# ---------------------------------------------------------------------------
# the synthesis gate: select-shuffles + egraph extract
# ---------------------------------------------------------------------------

GATED_PTX = """
.visible .entry gated(.param .u64 a, .param .u64 b)
{
    .reg .pred %p<2>;
    .reg .u32 %r<8>;
    .reg .u64 %rd<8>;
    ld.param.u64 %rd1, [a];
    ld.param.u64 %rd2, [b];
    mov.u32 %r1, %tid.x;
    setp.lt.u32 %p1, %r1, 16;
    @%p1 bra OTHER;
    mul.wide.u32 %rd3, %r1, 4;
    add.u64 %rd4, %rd1, %rd3;
    ld.global.u32 %r2, [%rd4];
    add.u64 %rd5, %rd4, 4;
    ld.global.u32 %r3, [%rd5];
    add.u32 %r4, %r2, %r3;
    add.u64 %rd6, %rd2, %rd3;
    st.global.u32 [%rd6], %r4;
    bra DONE;
OTHER:
    mul.wide.u32 %rd3, %r1, 4;
    add.u64 %rd6, %rd2, %rd3;
    st.global.u32 [%rd6], %r1;
DONE:
    ret;
}
"""

# identical loads, but the divergent branch only guards a pure exit —
# the paper's ubiquitous bounds-check shape, which synthesis may keep
UNGATED_PTX = """
.visible .entry ungated(.param .u64 a, .param .u64 b)
{
    .reg .pred %p<2>;
    .reg .u32 %r<8>;
    .reg .u64 %rd<8>;
    ld.param.u64 %rd1, [a];
    ld.param.u64 %rd2, [b];
    mov.u32 %r1, %tid.x;
    setp.ge.u32 %p1, %r1, 16;
    @%p1 bra DONE;
    mul.wide.u32 %rd3, %r1, 4;
    add.u64 %rd4, %rd1, %rd3;
    ld.global.u32 %r2, [%rd4];
    add.u64 %rd5, %rd4, 4;
    ld.global.u32 %r3, [%rd5];
    add.u32 %r4, %r2, %r3;
    add.u64 %rd6, %rd2, %rd3;
    st.global.u32 [%rd6], %r4;
DONE:
    ret;
}
"""


def test_gate_rejects_divergent_shuffle_statically():
    """A shuffle opportunity inside a JOIN-divergent region is dropped
    before synthesis; the same pair under an exit guard survives."""
    from repro.core.driver import Compiler

    with Compiler(jobs=0) as cc:
        gated = cc.compile(GATED_PTX, cache=None)
        ungated = cc.compile(UNGATED_PTX, cache=None)
    assert gated.n_shuffles == 0
    assert "shfl" not in gated.ptx
    assert gated.lint_counters.get("lint_gated_pairs") == 1
    assert ungated.n_shuffles == 1
    assert "shfl" in ungated.ptx
    assert "lint_gated_pairs" not in ungated.lint_counters


def test_gate_pairs_does_not_mutate_shared_detection():
    from repro.core.analysis.uniformity import gate_pairs
    from repro.core.emulator.machine import emulate
    from repro.core.passes.context import KernelContext, PipelineConfig
    from repro.core.ptx.parser import parse
    from repro.core.synthesis.detect import detect

    kernel = parse(GATED_PTX).kernels[0]
    detection = detect(kernel, emulate(kernel))
    assert detection.pairs
    before = list(detection.pairs)
    ctx = KernelContext(kernel, PipelineConfig())
    gated, dropped, widened = gate_pairs(ctx, detection)
    assert dropped == len(before)
    assert widened == 0              # widening is off by default
    assert gated is not detection
    assert detection.pairs == before     # input untouched


def test_extract_freezes_join_blocks():
    """The saturated pipeline never rewrites inside a JOIN region: the
    frozen-block counter fires on branchy and the result still passes
    the differential soundness gate."""
    from repro.core.driver import Compiler

    with Compiler(jobs=0, saturate=True) as cc:
        result = cc.compile(BRANCHY_PTX, cache=None)
    sc = result.saturation_counters
    assert sc.get("sat_divergent_blocks_frozen") == 3
    assert sc.get("sat_soundness_failures") == 0


def test_saturated_suite_has_no_gate_failures():
    """KernelGen under saturate=on: the static freeze leaves zero work
    for the dynamic differential gate to reject."""
    from repro.core.driver import Compiler
    from repro.core.frontend.kernelgen import all_benches
    from repro.core.frontend.stencil import lower_to_ptx
    from repro.core.ptx import Module

    module = Module(kernels=[lower_to_ptx(b.program)
                             for b in all_benches().values()])
    with Compiler(jobs=0, saturate=True) as cc:
        result = cc.compile(module, cache=None)
    sc = result.saturation_counters
    assert sc.get("sat_soundness_failures", 0) == 0


# ---------------------------------------------------------------------------
# compiler integration: the verify-ptx pass + diagnostics
# ---------------------------------------------------------------------------

def test_lint_off_by_default():
    from repro.core.driver import Compiler
    with Compiler(jobs=0) as cc:
        result = cc.compile(BRANCHY_PTX, cache=None)
    assert "verify-ptx" not in result.pass_times
    assert result.findings == []
    assert not [d for d in result.diagnostics if d.source == "verify-ptx"]


def test_lint_warn_surfaces_findings_as_diagnostics():
    from repro.core.driver import Compiler, Severity
    with Compiler(jobs=0, lint="warn") as cc:
        result = cc.compile(_corpus("width_mismatch.ptx"), cache=None)
    assert "verify-ptx" in result.pass_times
    [f] = result.findings
    assert f.code == "width-mismatch"
    [d] = [d for d in result.diagnostics if d.source == "verify-ptx"]
    assert d.severity == Severity.WARNING
    assert d.code == "width-mismatch"
    assert d.location == "uid:2"
    assert d.kernel == "width_mismatch"
    assert result.lint_counters.get("lint_width_mismatch") == 1


def test_lint_strict_escalates_warnings_to_errors():
    from repro.core.driver import Compiler, Severity
    with Compiler(jobs=0, lint="strict") as cc:
        result = cc.compile(_corpus("width_mismatch.ptx"), cache=None)
    [d] = [d for d in result.diagnostics if d.source == "verify-ptx"]
    assert d.severity == Severity.ERROR
    # NOTEs stay NOTEs even under strict
    with Compiler(jobs=0, lint="strict") as cc:
        branchy = cc.compile(BRANCHY_PTX, cache=None)
    [d] = [d for d in branchy.diagnostics if d.source == "verify-ptx"]
    assert d.severity == Severity.NOTE


def test_lint_option_validated():
    from repro.core.driver.options import CompilerOptions
    with pytest.raises(ValueError):
        CompilerOptions(lint="bogus")


def test_diagnostics_dedupe_same_kernel_twice():
    """The same kernel appearing twice in one module re-derives the
    same coded diagnostic; the result carries it once."""
    from repro.core.driver import Compiler

    module_text = _corpus("width_mismatch.ptx") \
        + _corpus("width_mismatch.ptx")
    with Compiler(jobs=0, lint="warn") as cc:
        result = cc.compile(module_text, cache=None)
    assert len(result.reports) == 2
    coded = [d for d in result.diagnostics if d.code == "width-mismatch"]
    assert len(coded) == 1


def test_dedupe_diagnostics_unit():
    from repro.core.driver.result import (
        Diagnostic, Severity, dedupe_diagnostics)
    a = Diagnostic(Severity.WARNING, "m", kernel="k",
                   code="c", location="uid:1")
    b = Diagnostic(Severity.WARNING, "different message", kernel="k",
                   code="c", location="uid:1")
    c = Diagnostic(Severity.WARNING, "m", kernel="k",
                   code="c", location="uid:2")
    plain = Diagnostic(Severity.NOTE, "m")
    out = dedupe_diagnostics([a, b, c, plain, plain])
    assert out == [a, c, plain]


def test_wire_form_roundtrips_findings():
    from repro.core.driver import CompileResult, Compiler
    with Compiler(jobs=0, lint="warn") as cc:
        result = cc.compile(_corpus("shared_race.ptx"), cache=None)
    back = CompileResult.from_json_dict(
        json.loads(json.dumps(result.to_json_dict())))
    assert [f.to_dict() for f in back.findings] \
        == [f.to_dict() for f in result.findings]
    [d] = [d for d in back.diagnostics if d.source == "verify-ptx"]
    assert d.code == "shared-race" and d.location == "uid:6:st:3"
    assert back.lint_counters == result.lint_counters


def test_cached_recompile_keeps_findings():
    """Findings ride the KernelReport, so a cache hit reproduces them."""
    from repro.core.driver import Compiler
    with Compiler(jobs=0, lint="warn") as cc:
        first = cc.compile(_corpus("undef_use.ptx"))
        second = cc.compile(_corpus("undef_use.ptx"))
    assert second.cached
    assert [f.to_dict() for f in second.findings] \
        == [f.to_dict() for f in first.findings]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_clean_corpus_exits_zero(capsys):
    from repro.core.analysis.lint import main
    assert main(["--corpus", "all", "--strict"]) == 0
    out = capsys.readouterr().out
    assert "0 error(s), 0 warning(s), 0 note(s)" in out


def test_cli_strict_fails_on_corpus_files(capsys):
    from repro.core.analysis.lint import main
    files = sorted(glob.glob(os.path.join(CORPUS_DIR, "*.ptx")))
    assert main(["--strict", *files]) == 1
    out = capsys.readouterr().out
    assert "4 error(s), 3 warning(s), 2 note(s)" in out
    # --strict is an alias of the default WARNING threshold
    assert main(files) == 1
    # the historical ERROR-only gate also trips — four errors planted
    assert main(["--errors-only", *files]) == 1


def test_cli_exit_code_contract(capsys):
    """0 clean / 1 findings >= WARNING / 2 usage error."""
    from repro.core.analysis.lint import main
    proven = os.path.join(CORPUS_DIR, "mask_reg_full.ptx")
    warn = os.path.join(CORPUS_DIR, "mask_loop_carried.ptx")
    assert main([proven]) == 0           # NOTEs never fail a build
    assert main([warn]) == 1             # WARNING trips the default
    assert main(["--errors-only", warn]) == 0
    assert main([os.path.join(CORPUS_DIR, "no_such_file.ptx")]) == 2
    capsys.readouterr()


def test_cli_json_envelope(capsys):
    from repro.core.analysis.lint import (
        JSON_SCHEMA, JSON_SCHEMA_VERSION, main)
    path = os.path.join(CORPUS_DIR, "undef_use.ptx")
    assert main(["--json", path]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["schema"] == JSON_SCHEMA
    assert payload["schema_version"] == JSON_SCHEMA_VERSION
    assert payload["n_kernels"] == 1
    [f] = payload["findings"]
    assert f["code"] == "undef-use"
    assert f["severity"] == "ERROR"
    assert payload["summary"] == {"errors": 1, "warnings": 0,
                                  "notes": 0, "proven_masks": 0}


def test_cli_synthesized_proves_every_membermask(capsys):
    """--synthesized compiles first, then lints the emitted shuffles:
    every synthesized full-mask shfl.sync must be PROVEN-OK."""
    from repro.core.analysis.lint import main
    assert main(["--bench", "jacobi", "--synthesized",
                 "--target", "volta", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    s = payload["summary"]
    assert s["errors"] == 0 and s["warnings"] == 0
    assert s["proven_masks"] > 0
    assert s["proven_masks"] == s["notes"]
    assert all(f["code"] == "membermask-proven"
               for f in payload["findings"])


# ---------------------------------------------------------------------------
# POST /lint on the serving front-end
# ---------------------------------------------------------------------------

def test_service_lint_endpoint():
    from repro.launch.ptx_service import PtxServiceClient, PtxServiceServer

    with PtxServiceServer(port=0, jobs=0) as server:
        server.start()
        client = PtxServiceClient(server.host, server.port)
        clean = client.lint(bench="jacobi")
        assert clean["clean"] is True
        assert clean["findings"] == [] and clean["n_kernels"] == 1

        buggy = client.lint(ptx=_corpus("div_shfl.ptx"))
        assert buggy["clean"] is False
        assert [f["code"] for f in buggy["findings"]] == ["divergent-shfl"]
        assert buggy["counts"]["lint_divergent_shfl"] == 1

        stats = client.stats()
        assert stats["requests"] == 2
        assert stats["lint_counters"]["lint_divergent_shfl"] == 1
        assert stats["lint_counters"]["lint_errors"] == 1
        # lint_ keys never leak into the emulator section
        assert not any(k.startswith("lint_")
                       for k in stats["emulator_counters"])

        with pytest.raises(RuntimeError, match="HTTP 400"):
            client.lint(ptx="x", bench="jacobi")
        with pytest.raises(RuntimeError, match="HTTP 400"):
            client.lint(ptx="no kernels here")
