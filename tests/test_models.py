"""Model-zoo tests: per-arch reduced-config smoke (forward/train step on
CPU, shape + finiteness), serve-path consistency, blockwise attention
and SSD equivalences, chunked CE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # degrade: property tests skip
    from _hypothesis_stub import given, settings, st

from repro.configs import all_configs, get_config, reduced
from repro.models import build_model, chunked_ce_loss, unbox
from repro.models.attention import AttnConfig, blockwise_attention, naive_attention
from repro.models.mamba2 import SSMConfig, apply_mamba2, decode_step, init_mamba2, ssd_chunked

RNG = np.random.default_rng(0)
ARCHS = sorted(all_configs())


def _batch(cfg, B, S):
    b = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        b["media"] = jnp.asarray(
            RNG.standard_normal((B, cfg.n_media_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            RNG.standard_normal((B, cfg.n_frames, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one forward + backward step, finite."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg, 2, 32)
    (loss, metrics), grads = jax.value_and_grad(
        model.loss, has_aux=True)(params, batch)
    assert jnp.isfinite(loss), arch
    gnorm = sum(float(jnp.sum(jnp.square(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_serve_consistency(arch):
    """prefill+decode logits == full-forward logits at matching positions."""
    cfg = reduced(get_config(arch)).replace(q_block=4, kv_block=4)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    B, S = 2, 16
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    batch = _batch(cfg, B, S)
    batch["tokens"] = toks[:, :S]
    h, _ = model.hidden(params, batch)
    logits_full = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                             params["embed"]["table"].astype(jnp.float32))
    b2 = dict(batch)
    b2["tokens"] = toks[:, :S - 1]
    lg_p, cache = model.prefill(params, b2, max_len=S)
    np.testing.assert_allclose(np.asarray(lg_p),
                               np.asarray(logits_full[:, S - 2]),
                               rtol=1e-4, atol=1e-4)
    lg_d, cache = model.decode_step(params, toks[:, S - 1], cache)
    np.testing.assert_allclose(np.asarray(lg_d),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=1e-4, atol=1e-4)


def test_bf16_layer_scan_dtypes():
    """Regression: bf16 configs must keep scan carries bf16 (the dry-run
    failure class for mamba/zamba)."""
    for arch in ("mamba2-1.3b", "zamba2-1.2b"):
        cfg = reduced(get_config(arch)).replace(dtype="bfloat16")
        model = build_model(cfg)
        params = unbox(model.init(jax.random.PRNGKey(0)))
        batch = _batch(cfg, 2, 32)
        loss, _ = model.loss(params, batch)
        assert jnp.isfinite(loss)


# ---------------------------------------------------------------------------
# attention equivalences
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.integers(9, 65), st.integers(1, 2),
       st.booleans())
def test_blockwise_equals_naive(B, S, KV, causal):
    H, Dh = KV * 2, 8
    cfg = AttnConfig(d_model=H * Dh, n_heads=H, n_kv_heads=KV, head_dim=Dh,
                     rope_theta=0, causal=causal, q_block=16, kv_block=16)
    q = jnp.asarray(RNG.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KV, Dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KV, Dh)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(blockwise_attention(q, k, v, cfg)),
        np.asarray(naive_attention(q, k, v, cfg)), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(1, 2), st.sampled_from([16, 32]), st.sampled_from([4, 8]))
def test_ssd_chunked_equals_sequential(B, L, chunk):
    H, P, G, N = 4, 8, 1, 16
    xh = jnp.asarray(RNG.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, L, G, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, L, G, N)), jnp.float32)
    h = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(L):
        da = jnp.exp(dt[:, t] * A[None])
        Bt = jnp.repeat(Bm[:, t], H // G, axis=1)
        Ct = jnp.repeat(Cm[:, t], H // G, axis=1)
        h = h * da[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bt, xh[:, t] * dt[:, t][..., None])
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ct, h))
    y_ref = jnp.stack(ys, 1)
    y, hf = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h),
                               rtol=1e-4, atol=1e-4)


def test_mamba_decode_equals_full():
    cfg = SSMConfig(d_model=32, d_state=16, head_dim=8, chunk=8)
    params = unbox(init_mamba2(jax.random.PRNGKey(0), cfg))
    B, L = 2, 16
    x = jnp.asarray(RNG.standard_normal((B, L, 32)), jnp.float32)
    full, (cs, ss) = apply_mamba2(params, x, cfg, return_state=True)
    st_ = (jnp.zeros((B, cfg.conv_width - 1, cfg.conv_dim)),
           jnp.zeros((B, cfg.n_heads, cfg.d_state, cfg.head_dim)))
    outs = []
    for t in range(L):
        o, st_ = decode_step(params, x[:, t], st_, cfg)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_[1]), np.asarray(ss),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# chunked CE
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 16, 32]), st.integers(0, 1))
def test_chunked_ce_equals_full(B, S, masked):
    V, D = 50, 12
    table = jnp.asarray(RNG.standard_normal((V, D)), jnp.float32)
    h = jnp.asarray(RNG.standard_normal((B, S, D)), jnp.float32)
    labels = np.asarray(RNG.integers(0, V, (B, S)), np.int32)
    if masked:
        labels[:, : S // 2] = -1
    labels = jnp.asarray(labels)
    got = chunked_ce_loss(table, h, labels, chunk=8)
    logits = jnp.einsum("bsd,vd->bsv", h, table)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0)
    want = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
