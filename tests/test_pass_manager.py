"""Pass-manager middle-end tests: analysis memoization + invalidation,
the content-addressed result cache, compat-wrapper byte-identity with
the legacy fixed chain, module-directive preservation, and the detect()
cross-flow / alias-store rejection rules."""

import pytest

import repro.core.passes.analyses as analyses_mod
from repro.core.emulator.machine import emulate
from repro.core.frontend.kernelgen import get_bench
from repro.core.frontend.stencil import lower_to_ptx
from repro.core.passes import (
    ANALYSIS_PASSES,
    CompileCache,
    KernelContext,
    PassPipeline,
    PipelineConfig,
    compile_kernel,
    compile_ptx,
)
from repro.core.passes.stages import SynthesizeShuffles
from repro.core.ptx import parse, parse_kernel, print_kernel, print_module
from repro.core.synthesis.codegen import synthesize
from repro.core.synthesis.detect import detect
from repro.core.synthesis.pipeline import ptxasw, ptxasw_kernel


def _count_emulate(monkeypatch):
    """Patch the analyses module's emulate with a counting wrapper."""
    calls = []

    def counting(kernel, **kw):
        calls.append(kernel.name)
        return emulate(kernel, **kw)

    monkeypatch.setattr(analyses_mod, "emulate", counting)
    return calls


# ---------------------------------------------------------------------------
# KernelContext: memoization + invalidation
# ---------------------------------------------------------------------------

def test_analysis_memoized(monkeypatch):
    calls = _count_emulate(monkeypatch)
    ctx = KernelContext(lower_to_ptx(get_bench("jacobi").program))
    flows1 = ctx.get("flows")
    flows2 = ctx.get("flows")
    det = ctx.get("detection")        # depends on flows: must reuse them
    assert flows1 is flows2
    assert len(calls) == 1
    assert det.n_shuffles == 6
    assert ctx.cached("flows") and ctx.cached("detection")


def test_invalidation_after_transform(monkeypatch):
    calls = _count_emulate(monkeypatch)
    ctx = KernelContext(lower_to_ptx(get_bench("laplacian").program))
    ctx.products["detection"] = ctx.get("detection")
    assert len(calls) == 1
    SynthesizeShuffles().run(ctx)
    # the transform invalidated every kernel-keyed analysis...
    assert not ctx.cached("flows") and not ctx.cached("detection")
    # ...but products survive (they describe the run, not the new body)
    assert ctx.products["detection"].n_shuffles == 2
    ctx.get("flows")                  # recomputes on the rewritten kernel
    assert len(calls) == 2


def test_cfg_and_dominators():
    ctx = KernelContext(lower_to_ptx(get_bench("jacobi").program))
    cfg = ctx.get("cfg")
    dom = ctx.get("dominators")
    assert len(cfg.blocks) >= 2
    assert cfg.block_of and len(cfg.block_of) == len(ctx.kernel.body)
    # entry dominates itself only; every block is dominated by the entry
    assert dom[cfg.entry] == {cfg.entry}
    assert all(cfg.entry in dom[b.bid] or b.bid == cfg.entry
               for b in cfg.blocks if b.preds or b.bid == cfg.entry)


def test_cfg_predicated_ret_keeps_fallthrough():
    ptx = """
.visible .entry k(.param .u64 a){
  .reg .pred %p<2>; .reg .b32 %r<4>; .reg .b64 %rd<6>; .reg .f32 %f<4>;
  ld.param.u64 %rd1, [a]; cvta.to.global.u64 %rd2, %rd1;
  mov.u32 %r1, %tid.x;
  setp.lt.s32 %p1, %r1, 8;
  @%p1 ret;
  ld.global.f32 %f1, [%rd2];
  st.global.f32 [%rd2], %f1;
  ret;
}
"""
    ctx = KernelContext(parse_kernel(ptx))
    cfg = ctx.get("cfg")
    dom = ctx.get("dominators")
    # the block ending in the guarded ret must fall through, and the
    # trailing block must be reachable (dominated by the entry)
    guarded = cfg.blocks[0]
    assert guarded.succs, "predicated ret dropped its fall-through edge"
    tail = cfg.blocks[guarded.succs[0]]
    assert cfg.entry in dom[tail.bid]


def test_cached_report_is_isolated():
    """Mutating a cache-served report must not poison later hits."""
    cache = CompileCache()
    kernel = lower_to_ptx(get_bench("jacobi").program)
    cfg = PipelineConfig()
    _, rep1 = compile_kernel(kernel, cfg, cache=cache)
    rep1.pass_times.clear()
    rep1.detection.pairs.clear()
    _, rep2 = compile_kernel(kernel, cfg, cache=cache)
    assert rep2.cached
    assert rep2.pass_times and rep2.detection.n_shuffles == 6, \
        "cache entry was mutated through a shared report reference"


def test_alias_facts_match_store_blocking():
    ptx = """
.visible .entry k(.param .u64 a){
  .reg .b32 %r<8>; .reg .b64 %rd<8>; .reg .f32 %f<8>;
  ld.param.u64 %rd1, [a]; cvta.to.global.u64 %rd2, %rd1;
  mov.u32 %r1, %tid.x;
  mul.wide.s32 %rd3, %r1, 4;
  add.s64 %rd4, %rd2, %rd3;
  ld.global.f32 %f1, [%rd4];
  st.global.f32 [%rd4], %f1;
  ret;
}
"""
    ctx = KernelContext(parse_kernel(ptx))
    facts = ctx.get("alias")
    flows = ctx.get("flows")
    fid = next(fr.flow_id for fr in flows if fr.loads())
    load = next(iter(flows)).loads()[0]
    assert facts.clobbered(fid, load.order), \
        "the same-address store must register as a may-alias clobber"


# ---------------------------------------------------------------------------
# result cache
# ---------------------------------------------------------------------------

def test_cache_hit_skips_emulation(monkeypatch):
    calls = _count_emulate(monkeypatch)
    cache = CompileCache()
    kernel = lower_to_ptx(get_bench("gaussblur").program)
    text = print_module(parse(print_kernel(kernel)))

    out1, reps1 = compile_ptx(text, cache=cache)
    n_first = len(calls)
    assert n_first > 0 and not reps1[0].cached
    out2, reps2 = compile_ptx(text, cache=cache)
    assert len(calls) == n_first, "second compile must not re-emulate"
    assert reps2[0].cached
    assert out2 == out1, "cached output must be byte-identical"
    assert cache.stats.hits == 1 and cache.stats.misses == 1


def test_cache_distinguishes_config_and_passes():
    cache = CompileCache()
    kernel = lower_to_ptx(get_bench("jacobi").program)
    compile_kernel(kernel, PipelineConfig(mode="ptxasw"), cache=cache)
    compile_kernel(kernel, PipelineConfig(mode="nocorner"), cache=cache)
    pipeline = PassPipeline(passes=ANALYSIS_PASSES)
    pipeline.run_kernel(kernel, cache=cache)
    assert cache.stats.misses == 3 and cache.stats.hits == 0


def test_cached_kernel_is_isolated():
    """Mutating a cache-served kernel must not poison later hits."""
    cache = CompileCache()
    kernel = lower_to_ptx(get_bench("laplacian").program)
    cfg = PipelineConfig()
    out1, _ = compile_kernel(kernel, cfg, cache=cache)
    out1.body.clear()
    out2, rep2 = compile_kernel(kernel, cfg, cache=cache)
    assert rep2.cached and out2.body, "cache entry was mutated by a caller"


# ---------------------------------------------------------------------------
# compat wrapper: byte-identity with the legacy fixed chain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["jacobi", "gaussblur", "laplacian",
                                  "whispering", "wave13pt"])
def test_ptxasw_matches_legacy_chain(name):
    b = get_bench(name)
    kernel = lower_to_ptx(b.program)
    # the pre-pass-manager chain, run by hand
    legacy = synthesize(kernel,
                        detect(kernel, emulate(kernel),
                               max_delta=b.max_delta),
                        mode="ptxasw")
    via_pipeline, rep = ptxasw_kernel(kernel, max_delta=b.max_delta)
    assert print_kernel(via_pipeline) == print_kernel(legacy)
    assert rep.detection.n_shuffles == b.expect_shuffles


def test_ptxasw_text_path_matches_legacy_chain():
    kernel = lower_to_ptx(get_bench("jacobi").program)
    text = print_module(parse(print_kernel(kernel)))
    module = parse(text)
    legacy_module = parse(text)
    legacy_module.kernels = [
        synthesize(k, detect(k, emulate(k)), mode="ptxasw")
        for k in module.kernels
    ]
    out_text, _ = ptxasw(text)
    assert out_text == print_module(legacy_module)


def test_module_directives_preserved_through_pipeline():
    kernel = lower_to_ptx(get_bench("vecadd").program)
    text = (".version 8.2\n.target sm_90a\n.address_size 64\n\n"
            + print_kernel(kernel))
    out_text, _ = ptxasw(text)
    assert ".version 8.2" in out_text
    assert ".target sm_90a" in out_text
    # defaults still apply when the source declared nothing
    out_default, _ = ptxasw(print_kernel(kernel))
    assert ".version 7.6" in out_default and ".target sm_70" in out_default


def test_run_module_parallel_matches_serial():
    texts = [print_kernel(lower_to_ptx(get_bench(n).program))
             for n in ("jacobi", "laplacian", "gradient", "vecadd")]
    module_text = ".version 7.6\n.target sm_70\n.address_size 64\n\n" \
        + "\n".join(texts)
    serial, _ = compile_ptx(module_text, jobs=1, cache=None)
    parallel, reps = compile_ptx(module_text, jobs=4, cache=None)
    assert parallel == serial
    assert [r.name for r in reps] == ["jacobi", "laplacian",
                                      "gradient", "vecadd"]


# ---------------------------------------------------------------------------
# detect(): cross-flow consistency + alias-store blocking
# ---------------------------------------------------------------------------

_CROSS_FLOW_TEMPLATE = """
.visible .entry k(.param .u64 a){{
  .reg .pred %p<2>; .reg .b32 %r<8>; .reg .b64 %rd<8>; .reg .f32 %f<4>;
  ld.param.u64 %rd1, [a]; cvta.to.global.u64 %rd2, %rd1;
  mov.u32 %r1, %tid.x;
  mul.wide.s32 %rd3, %r1, 4;
  add.s64 %rd4, %rd2, %rd3;
  setp.lt.s32 %p1, %r1, 16;
  @%p1 bra $A;
  add.s64 %rd5, %rd4, {off_fall};
  bra $J;
$A:
  add.s64 %rd5, %rd4, {off_taken};
$J:
  ld.global.f32 %f1, [%rd4];
  ld.global.f32 %f2, [%rd5];
  st.global.f32 [%rd2], %f2;
  ret;
}}
"""


def _detect_text(ptx):
    kernel = parse_kernel(ptx)
    return detect(kernel, emulate(kernel))


def test_detect_cross_flow_disagreement_rejects_pair():
    """Two flows reaching the same load with different deltas -> no pair."""
    det = _detect_text(_CROSS_FLOW_TEMPLATE.format(off_fall=8, off_taken=4))
    assert det.n_flows >= 2
    assert det.n_shuffles == 0


def test_detect_cross_flow_agreement_keeps_pair():
    """Control: both flows agree on delta 1 -> the pair survives."""
    det = _detect_text(_CROSS_FLOW_TEMPLATE.format(off_fall=4, off_taken=4))
    assert det.n_flows >= 2
    assert det.n_shuffles == 1
    assert det.pairs[0].delta == 1


_ALIAS_STORE_TEMPLATE = """
.visible .entry k(.param .u64 a, .param .u64 b){{
  .reg .b32 %r<8>; .reg .b64 %rd<10>; .reg .f32 %f<8>;
  ld.param.u64 %rd1, [a]; cvta.to.global.u64 %rd2, %rd1;
  ld.param.u64 %rd6, [b]; cvta.to.global.u64 %rd7, %rd6;
  mov.u32 %r1, %tid.x;
  mul.wide.s32 %rd3, %r1, 4;
  add.s64 %rd4, %rd2, %rd3;
  ld.global.f32 %f1, [%rd4];
{store}  ld.global.f32 %f2, [%rd4+4];
  st.global.f32 [%rd7+64], %f2;
  ret;
}}
"""


def test_detect_intervening_alias_store_blocks_pair():
    """A store through another pointer may alias the source -> no pair."""
    blocked = _ALIAS_STORE_TEMPLATE.format(
        store="  st.global.f32 [%rd7], %f1;\n")
    det = _detect_text(blocked)
    assert det.n_shuffles == 0


def test_detect_no_store_keeps_pair():
    det = _detect_text(_ALIAS_STORE_TEMPLATE.format(store=""))
    assert det.n_shuffles == 1
    assert det.pairs[0].delta == 1
