"""HTTP serving front-end tests: endpoint contract, byte-identity of
served PTX with in-process compilation, error reporting, and the
bench-list parsing regression (whitespace / trailing commas / unknown
names)."""

import json
import threading
from http.client import HTTPConnection

import pytest

from repro.core.driver import Compiler
from repro.core.frontend.kernelgen import get_bench
from repro.core.frontend.stencil import lower_to_ptx
from repro.core.ptx import print_kernel
from repro.launch.ptx_service import (
    BackpressureError,
    PtxServiceClient,
    PtxServiceServer,
    parse_bench_list,
)


@pytest.fixture(scope="module")
def server(tmp_path_factory):
    srv = PtxServiceServer(port=0, jobs=2,
                           cache_dir=str(tmp_path_factory.mktemp("cache")))
    srv.start()
    yield srv
    srv.close()


@pytest.fixture(scope="module")
def client(server):
    return PtxServiceClient(server.host, server.port)


def _vecadd_ptx():
    return print_kernel(lower_to_ptx(get_bench("vecadd").program))


# ---------------------------------------------------------------------------
# bench-list parsing (the satellite bugfix)
# ---------------------------------------------------------------------------

def test_parse_bench_list_tolerates_whitespace_and_trailing_commas():
    assert parse_bench_list("jacobi, laplacian,") == ["jacobi", "laplacian"]
    assert parse_bench_list("  vecadd ") == ["vecadd"]
    assert parse_bench_list("jacobi,,gradient") == ["jacobi", "gradient"]


def test_parse_bench_list_names_unknown_and_valid_set():
    with pytest.raises(ValueError, match=r"unknown bench\(es\) nope.*jacobi"):
        parse_bench_list("jacobi, nope")
    with pytest.raises(ValueError, match="no benchmark names"):
        parse_bench_list(" ,, ")


def test_cli_rejects_bad_bench_list_with_clear_message(capsys):
    from repro.launch import ptx_service
    with pytest.raises(SystemExit):
        ptx_service.main(["--requests", "1", "--benches", "jacobi,nope"])
    err = capsys.readouterr().err
    assert "unknown bench(es) nope" in err and "vecadd" in err


# ---------------------------------------------------------------------------
# endpoints
# ---------------------------------------------------------------------------

def test_healthz(client):
    assert client.healthz() is True


def test_close_without_start_does_not_hang():
    """shutdown() waits on an event only serve_forever() sets; closing
    a never-started server must return promptly, not deadlock."""
    with PtxServiceServer():
        pass                    # __exit__ closes an unstarted server
    srv = PtxServiceServer()
    srv.close()


def test_compile_ptx_byte_identical_to_in_process(client):
    text = _vecadd_ptx()
    resp = client.compile(ptx=text)
    local = Compiler().compile(text)
    assert resp["ptx"] == local.ptx, \
        "HTTP-served PTX must be byte-identical to Compiler.compile"
    assert resp["reports"][0]["name"] == "vecadd"
    assert resp["frontend"] == "ptx"


def test_compile_bench_with_options_and_result_rebuild(client):
    res = client.compile_result(bench="jacobi", max_delta=31)
    assert res.n_shuffles == 6
    assert res.by_kernel["jacobi"].detection.n_loads == 9
    local = Compiler().compile(get_bench("jacobi"))
    assert res.ptx == local.ptx


def test_repeat_requests_served_from_cache(client):
    client.compile(bench="laplacian")
    before = client.stats()["cache"]
    resp = client.compile(bench="laplacian")
    after = client.stats()["cache"]
    assert resp["reports"][0]["cached"]
    assert after["hits"] == before["hits"] + 1


def test_stats_endpoint_shape(client):
    client.compile(bench="vecadd")
    st = client.stats()
    assert st["ok"] and st["requests"] >= 1 and st["uptime_s"] >= 0
    assert {"hits", "misses", "disk_hits", "disk_misses",
            "hit_rate"} <= set(st["cache"])
    assert st["disk"] is not None and st["disk"]["entries"] >= 1
    assert isinstance(st["pass_times"], dict)


# ---------------------------------------------------------------------------
# error contract
# ---------------------------------------------------------------------------

def _raw_post(server, path, body: bytes, content_length=None):
    conn = HTTPConnection(server.host, server.port, timeout=60)
    try:
        headers = {"Content-Type": "application/json"}
        if content_length is not None:
            headers["Content-Length"] = str(content_length)
        conn.request("POST", path, body=body, headers=headers)
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read() or b"{}")
    finally:
        conn.close()


def test_unknown_bench_is_400_naming_valid_set(client):
    with pytest.raises(RuntimeError, match="400.*unknown bench.*vecadd"):
        client.compile(bench="nope")


def test_bad_requests_are_4xx_not_500(server, client):
    with pytest.raises(RuntimeError, match="400.*exactly one"):
        client.compile()                                # neither ptx nor bench
    with pytest.raises(RuntimeError, match="400"):
        client._request("POST", "/compile",
                        {"ptx": "x", "bench": "jacobi"})  # both
    with pytest.raises(RuntimeError, match=r"400.*unknown option\(s\)"):
        client.compile(bench="jacobi", jobs=3)          # session knob
    status, payload = _raw_post(server, "/compile", b"{not json")
    assert status == 400 and "not JSON" in payload["error"]
    with pytest.raises(RuntimeError, match="400"):
        client.compile(ptx="this is not ptx at all")


def test_unknown_paths_are_404(server, client):
    with pytest.raises(RuntimeError, match="404"):
        client._request("GET", "/nope")
    status, _ = _raw_post(server, "/nope", b"{}")
    assert status == 404


def test_errors_counted_but_service_stays_up(client):
    before = client.stats()["errors"]
    with pytest.raises(RuntimeError):
        client.compile(bench="nope")
    st = client.stats()
    assert st["errors"] == before + 1
    assert client.healthz(), "an error response must not take the service down"


# ---------------------------------------------------------------------------
# body-size cap (the 413 satellite bugfix)
# ---------------------------------------------------------------------------

def test_oversized_body_is_413_and_service_stays_up():
    with PtxServiceServer(max_body_bytes=64) as srv:
        srv.start()
        body = json.dumps({"ptx": "x" * 200}).encode()
        status, payload = _raw_post(srv, "/compile", body)
        assert status == 413 and "64-byte limit" in payload["error"]
        client = PtxServiceClient(srv.host, srv.port)
        assert client.healthz(), "a 413 must not take the service down"
        # small bodies still work through the same server
        with pytest.raises(RuntimeError, match="400"):
            client.compile(ptx="tiny")


def test_declared_oversized_length_is_refused_before_reading(server):
    # the header alone triggers the refusal: the 1-byte body is never
    # buffered (that is the point of the cap)
    status, payload = _raw_post(server, "/compile", b"x",
                                content_length=server.max_body_bytes + 1)
    assert status == 413 and "exceeds" in payload["error"]


# ---------------------------------------------------------------------------
# client retry policy (transport robustness satellite)
# ---------------------------------------------------------------------------

def _one_shot_server(scripts):
    """A raw socket server playing ``scripts`` once each per
    connection: ``None`` means slam the connection shut (a retryable
    transport error); bytes are written verbatim as the response."""
    import socket

    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    sock.listen(8)
    port = sock.getsockname()[1]

    def run():
        for script in scripts:
            conn, _ = sock.accept()
            try:
                conn.recv(65536)
                if script is not None:
                    conn.sendall(script)
            finally:
                conn.close()
        sock.close()

    t = threading.Thread(target=run, daemon=True)
    t.start()
    return port, t


def _http_response(status_line, body=b"{}", extra_headers=()):
    head = [status_line,
            "Content-Type: application/json",
            f"Content-Length: {len(body)}",
            *extra_headers, "", ""]
    return "\r\n".join(head).encode() + body


def test_client_retries_transport_errors_with_counter():
    ok = _http_response("HTTP/1.1 200 OK", body=b'{"ok": true}')
    port, t = _one_shot_server([None, ok])   # first conn dies mid-air
    client = PtxServiceClient("127.0.0.1", port, retries=2,
                              backoff_s=0.001)
    assert client.healthz() is True
    t.join(timeout=10)
    assert client.counters == {"requests": 1, "retries": 1,
                               "backpressure": 0}


def test_client_gives_up_after_retry_budget():
    client = PtxServiceClient("127.0.0.1", 9, retries=2, backoff_s=0.001)
    with pytest.raises(ConnectionRefusedError):
        client.healthz()                     # nothing listens on port 9
    assert client.counters["retries"] == 2


def test_503_surfaces_backpressure_not_blind_retry():
    resp = _http_response("HTTP/1.1 503 Service Unavailable",
                          body=b'{"error": "queue full"}',
                          extra_headers=("Retry-After: 7",))
    port, t = _one_shot_server([resp])
    client = PtxServiceClient("127.0.0.1", port, retries=3,
                              backoff_s=0.001)
    with pytest.raises(BackpressureError) as exc:
        client.compile(bench="vecadd")
    t.join(timeout=10)
    assert exc.value.retry_after == 7.0
    assert client.counters == {"requests": 1, "retries": 0,
                               "backpressure": 1}, \
        "an HTTP 503 response is the caller's pacing decision, not a " \
        "transport retry"


# ---------------------------------------------------------------------------
# modes
# ---------------------------------------------------------------------------

def test_bench_mode_end_to_end(tmp_path, capsys):
    from repro.launch import ptx_service
    summary = ptx_service.main([
        "--bench", "--requests", "8", "--clients", "2",
        "--benches", "vecadd, divergence,",
        "--cache-dir", str(tmp_path)])
    assert summary["requests"] == 8
    assert summary["distinct_benches"] == 2
    assert summary["req_per_s"] > 0
    assert "ptx_service bench OK" in capsys.readouterr().out

    # the same dir warm: a second in-process "replica" run must verify
    # the zero-emulation disk path end to end
    summary2 = ptx_service.main([
        "--requests", "6", "--jobs", "2",
        "--benches", "vecadd,divergence",
        "--cache-dir", str(tmp_path), "--expect-warm-disk"])
    assert "emulate-flows" not in summary2["pass_times"]
    assert "warm-from-disk verified" in capsys.readouterr().out
