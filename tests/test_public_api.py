"""Public-API snapshot: the exported surface of the driver facade and
the pass-manager package is pinned here so accidental drift breaks the
build (this file), not downstream users.

If you are changing the API *on purpose*, update the snapshot below
and the ARCHITECTURE.md "Driver API" section together.
"""

import repro.core.driver as driver
import repro.core.passes as passes

DRIVER_API = {
    "Compiler",
    "CompilerOptions",
    "CompileResult",
    "DetectionSummary",
    "Diagnostic",
    "NormalizedSource",
    "PreparedSource",
    "Severity",
    "Source",
    "SourceFrontend",
    "default_compiler",
    "frontend_names",
    "normalize_source",
    "register_frontend",
}

PASSES_API = {
    "ANALYSIS_PASSES",
    "ANALYSIS_REGISTRY",
    "AliasFacts",
    "BasicBlock",
    "CFG",
    "CacheStats",
    "CompileCache",
    "DEFAULT_PASSES",
    "DiskCache",
    "GLOBAL_CACHE",
    "KernelContext",
    "KernelReport",
    "PASS_REGISTRY",
    "Pass",
    "PassPipeline",
    "PipelineConfig",
    "SYNTHESIS_PASSES",
    "TargetVariant",
    "analyze_kernel",
    "compile_for_targets",
    "compile_kernel",
    "compile_module",
    "compile_ptx",
    "default_pipeline",
    "register_analysis",
    "register_pass",
    "set_default_jobs",
}


def test_driver_exports_exactly():
    assert set(driver.__all__) == DRIVER_API
    missing = [n for n in driver.__all__ if not hasattr(driver, n)]
    assert not missing, f"__all__ names not importable: {missing}"


def test_passes_exports_exactly():
    assert set(passes.__all__) == PASSES_API
    missing = [n for n in passes.__all__ if not hasattr(passes, n)]
    assert not missing, f"__all__ names not importable: {missing}"


def test_star_import_surfaces_match_snapshot():
    ns_driver, ns_passes = {}, {}
    exec("from repro.core.driver import *", ns_driver)  # noqa: S102
    exec("from repro.core.passes import *", ns_passes)  # noqa: S102
    assert DRIVER_API <= set(ns_driver)
    assert PASSES_API <= set(ns_passes)


def test_driver_reachable_from_core():
    import repro.core
    assert repro.core.driver is driver   # lazy re-export stays wired
