"""Relational abstract interpreter (PR 10): unit + integration tests.

Covers the per-lane constraint solver (``lanes_may``), the relational
fixpoint (constant propagation, loop-carried widening soundness), the
survivor-set analysis (exit-guard prefixes, vacuous-guard
declassification), the membermask prover, and proof-widened synthesis:
pairs kept past the raw JOIN gate, survivor-prefix clamps, the
differential re-validation, and byte-identity when widening is off.
"""

import json
import os

import pytest

CORPUS_DIR = os.path.join(os.path.dirname(__file__), "lint_corpus")


def _corpus(name: str) -> str:
    with open(os.path.join(CORPUS_DIR, name), "r", encoding="utf-8") as fh:
        return fh.read()


def _ctx(text: str, **config):
    from repro.core.passes.context import KernelContext, PipelineConfig
    from repro.core.ptx.parser import parse
    import repro.core.passes.analyses  # noqa: F401  (registers cfg etc.)
    import repro.core.analysis.uniformity  # noqa: F401
    import repro.core.analysis.relational  # noqa: F401
    return KernelContext(parse(text).kernels[0], PipelineConfig(**config))


FULL = 0xFFFFFFFF


# ---------------------------------------------------------------------------
# the per-lane constraint solver
# ---------------------------------------------------------------------------

def test_lanes_may_unsigned_guard_asymmetry():
    """lane = 32q + lambda with q unknown: ``tid.x < 16`` pins the
    surviving lanes to the 0xffff prefix, but ``tid.x >= 16`` excludes
    nothing (lanes 0-15 of warp 1 satisfy it)."""
    from repro.core.analysis.relational import lanes_may
    from repro.core.symbolic.terms import Cmp, Term

    tid = Term.sym("tid.x")
    lt16 = Cmp("lt", tid, Term.const_(16), signed=False)
    assert lanes_may(lt16, "tid.x") == 0xFFFF
    assert lanes_may(lt16.negate(), "tid.x") == FULL


def test_lanes_may_laneid_and_eq():
    from repro.core.analysis.relational import lanes_may
    from repro.core.symbolic.terms import Cmp, Term

    laneid = Term.sym("laneid")
    ge32 = Cmp("ge", laneid, Term.const_(32), signed=False)
    assert lanes_may(ge32, "tid.x") == 0           # vacuous guard
    assert lanes_may(ge32.negate(), "tid.x") == FULL
    eq5 = Cmp("eq", Term.sym("tid.x"), Term.const_(5), signed=False)
    assert lanes_may(eq5, "tid.x") == (1 << 5)


def test_lanes_may_conjunction_and_unknown():
    from repro.core.analysis.relational import lanes_may
    from repro.core.symbolic.terms import Cmp, Term, bool_and

    tid = Term.sym("tid.x")
    lt8 = Cmp("lt", tid, Term.const_(8), signed=False)
    ge4 = Cmp("ge", tid, Term.const_(4), signed=False)
    # conjuncts are solved independently (each gets its own q), so the
    # conjunction is the intersection of the per-conjunct may-sets:
    # lt8 -> 0xff, ge4 -> full (warp 1 satisfies it for every lane)
    assert lanes_may(bool_and(lt8, ge4), "tid.x") == 0xFF
    # unknown expressions are conservatively the full warp
    assert lanes_may(None, "tid.x") == FULL
    opaque = Cmp("lt", Term.sym("x"), Term.sym("y"), signed=False)
    assert lanes_may(opaque, "tid.x") == FULL


def test_lane_invariant():
    from repro.core.analysis.relational import _lane_invariant
    from repro.core.symbolic.terms import Cmp, Term

    uni = Cmp("lt", Term.const_(2), Term.const_(4), signed=False)
    assert _lane_invariant(uni, "tid.x")
    div = Cmp("lt", Term.sym("tid.x"), Term.const_(16), signed=False)
    assert not _lane_invariant(div, "tid.x")
    # an opaque symbol might be lane-dependent: conservatively varying
    opaque = Cmp("lt", Term.sym("k"), Term.const_(4), signed=False)
    assert not _lane_invariant(opaque, "tid.x")
    # lane terms cancel across the comparison -> warp-uniform again
    tid = Term.sym("tid.x")
    cancel = Cmp("lt", tid.add(Term.const_(1)), tid, signed=False)
    assert _lane_invariant(cancel, "tid.x")


# ---------------------------------------------------------------------------
# the relational fixpoint
# ---------------------------------------------------------------------------

STRAIGHT_PTX = """
.visible .entry straight(.param .u64 a)
{
    .reg .u32 %r<6>;
    .reg .u64 %rd<4>;
    ld.param.u64 %rd1, [a];
    mov.u32 %r1, 5;
    add.u32 %r2, %r1, 3;
    shl.b32 %r3, %r2, 2;
    st.global.u32 [%rd1], %r3;
    ret;
}
"""


def test_fixpoint_constant_propagation():
    ctx = _ctx(STRAIGHT_PTX)
    rel = ctx.get("relational")
    cfg = ctx.get("cfg")
    env = rel.exit[cfg.entry]
    assert env.regs["%r1"].as_const == 5
    assert env.regs["%r2"].as_const == 8
    assert env.regs["%r3"].as_const == 32


def test_fixpoint_loop_carried_binding_dropped():
    """mask_loop_carried.ptx: %r4 is 0xffffffff on entry but shifted
    every trip — the loop-head intersection must drop the binding
    rather than keep the first-trip constant (a false PROVEN-OK)."""
    ctx = _ctx(_corpus("mask_loop_carried.ptx"))
    rel = ctx.get("relational")
    cfg = ctx.get("cfg")
    decoded = ctx.get("decoded")
    from repro.core.emulator.decode import K_SHFL
    [shfl] = [d for d in decoded if d.kind == K_SHFL]
    head = cfg.block_of[shfl.uid]
    got = rel.entry[head].regs.get("%r4")
    assert got is None or got.as_const is None
    # ...and the prover agrees: unprovable, not proven
    from repro.core.analysis.relational import prove_shfl_masks
    proof = prove_shfl_masks(ctx)[shfl.uid]
    assert proof.verdict == "unknown"


def test_prover_verdicts_on_corpus():
    from repro.core.analysis.relational import prove_shfl_masks
    from repro.core.emulator.decode import K_SHFL

    def _one(fname):
        ctx = _ctx(_corpus(fname))
        [shfl] = [d for d in ctx.get("decoded") if d.kind == K_SHFL]
        return ctx, prove_shfl_masks(ctx)[shfl.uid]

    _, p = _one("mask_reg_full.ptx")
    assert (p.verdict, p.via, p.mask) == ("proven", "const-reg", FULL)
    _, p = _one("mask_wrong.ptx")
    assert p.verdict == "noncovering"
    assert p.survivors & ~p.mask & FULL == 0xFFFF0000
    _, p = _one("mask_guarded_covering.ptx")
    assert (p.verdict, p.mask, p.survivors) == ("proven", 0xFFFF, 0xFFFF)


# ---------------------------------------------------------------------------
# survivor sets
# ---------------------------------------------------------------------------

def test_survivors_exit_guard_prefix():
    """tid.x >= 16 exits: the guarded region's survivor set is the
    0xffff prefix with a contiguous bound of 16 lanes."""
    from test_lint import UNGATED_PTX
    ctx = _ctx(UNGATED_PTX)
    surv = ctx.get("survivors")
    cfg = ctx.get("cfg")
    decoded = ctx.get("decoded")
    from repro.core.emulator.decode import K_LD
    guarded = {cfg.block_of[d.uid] for d in decoded
               if d.kind == K_LD and d.space == "global"}
    assert len(guarded) == 1
    [bid] = guarded
    assert surv.lanes[bid] == 0xFFFF
    assert surv.contiguous_bound(bid) == 16
    assert not surv.proven_full(bid)
    assert surv.proven_full(cfg.entry)
    assert surv.contiguous_bound(cfg.entry) is None   # full is not a clamp


VACUOUS_PTX = """
.visible .entry vacuous(.param .u64 a, .param .u64 b)
{
    .reg .pred %p<2>;
    .reg .u32 %r<8>;
    .reg .u64 %rd<8>;
    ld.param.u64 %rd1, [a];
    ld.param.u64 %rd2, [b];
    mov.u32 %r1, %tid.x;
    mov.u32 %r5, %laneid;
    setp.ge.u32 %p1, %r5, 32;
    @%p1 bra OTHER;
    mul.wide.u32 %rd3, %r1, 4;
    add.u64 %rd4, %rd1, %rd3;
    ld.global.u32 %r2, [%rd4];
    add.u64 %rd5, %rd4, 4;
    ld.global.u32 %r3, [%rd5];
    add.u32 %r4, %r2, %r3;
    add.u64 %rd6, %rd2, %rd3;
    st.global.u32 [%rd6], %r4;
    bra DONE;
OTHER:
    mul.wide.u32 %rd3, %r1, 4;
    add.u64 %rd6, %rd2, %rd3;
    st.global.u32 [%rd6], %r1;
DONE:
    ret;
}
"""


def test_survivors_declassify_vacuous_guard():
    """%laneid >= 32 is unsatisfiable: raw uniformity calls the branch
    JOIN (both sides do observable work), the survivor analysis proves
    the taken edge dead and declassifies the whole region."""
    from repro.core.analysis.uniformity import JOIN, UNIFORM
    ctx = _ctx(VACUOUS_PTX)
    info = ctx.get("uniformity")
    assert JOIN in info.branch_class.values()
    surv = ctx.get("survivors")
    assert surv.n_refined >= 1
    assert all(lvl == UNIFORM for lvl in surv.block_level)
    from repro.core.analysis.relational import refined_join_block_ids
    assert refined_join_block_ids(ctx) == frozenset()


# ---------------------------------------------------------------------------
# proof-widened synthesis
# ---------------------------------------------------------------------------

def test_widen_keeps_vacuously_gated_pair():
    from repro.core.driver import Compiler

    with Compiler(jobs=0) as cc:
        off = cc.compile(VACUOUS_PTX, cache=None)
    assert off.n_shuffles == 0
    assert off.lint_counters.get("lint_gated_pairs") == 1

    with Compiler(jobs=0, widen=True) as cc:
        on = cc.compile(VACUOUS_PTX, cache=None)
    assert on.n_shuffles == 1
    assert on.lint_counters.get("lint_widened_pairs") == 1
    assert "lint_widening_reverted" not in on.lint_counters


def test_widen_clamps_exit_guard_masks():
    """Under the tid.x < 16 exit guard the proven survivor prefix
    tightens the synthesized corner-case checks: activemask compared
    against 0xffff (not -1), the down-shuffle threshold drops from 30
    to 14, and the shfl.sync membermask names exactly the survivors."""
    from test_lint import UNGATED_PTX
    from repro.core.driver import Compiler

    with Compiler(jobs=0, target="volta") as cc:
        off = cc.compile(UNGATED_PTX, cache=None)
    assert off.n_shuffles == 1
    assert "0xffffffff" in off.ptx and "0xffff;" not in off.ptx

    with Compiler(jobs=0, target="volta", widen=True) as cc:
        on = cc.compile(UNGATED_PTX, cache=None)
    assert on.n_shuffles == 1
    assert on.lint_counters.get("lint_survivor_clamps") == 1
    assert "lint_widening_reverted" not in on.lint_counters
    assert "0xffff" in on.ptx
    assert "shfl.sync.down.b32" in on.ptx
    # the clamped membermask is self-provable by the lint prover
    from repro.core.analysis.lint import lint_source, summarize
    s = summarize(lint_source(on.ptx))
    assert s["errors"] == 0 and s["warnings"] == 0
    assert s["proven_masks"] == 1


def test_widen_off_is_byte_identical_and_cached_separately():
    from repro.core.driver import Compiler
    from repro.core.passes.context import PipelineConfig
    from test_lint import UNGATED_PTX

    with Compiler(jobs=0, target="volta") as cc:
        default = cc.compile(UNGATED_PTX, cache=None)
    with Compiler(jobs=0, target="volta", widen=False) as cc:
        explicit = cc.compile(UNGATED_PTX, cache=None)
    assert default.ptx == explicit.ptx
    assert PipelineConfig().cache_token \
        != PipelineConfig(widen=True).cache_token


def test_widened_suite_stays_differentially_sound():
    """widen=on over the full KernelGen suite: every widened decision
    re-validates through the differential gate (no silent divergence),
    and the synthesized shuffle count never regresses."""
    from repro.core.driver import Compiler
    from repro.core.frontend.kernelgen import all_benches
    from repro.core.frontend.stencil import lower_to_ptx
    from repro.core.ptx import Module

    module = Module(kernels=[lower_to_ptx(b.program)
                             for b in all_benches().values()])
    with Compiler(jobs=0, target="volta") as cc:
        off = cc.compile(module, cache=None)
    with Compiler(jobs=0, target="volta", widen=True) as cc:
        on = cc.compile(module, cache=None)
    assert on.n_shuffles >= off.n_shuffles
    assert "lint_widening_reverted" not in on.lint_counters


# ---------------------------------------------------------------------------
# finding dedup regression: one load, two racing stores
# ---------------------------------------------------------------------------

TWO_RACES_PTX = """
.visible .entry two_races(.param .u64 a)
{
    .reg .u32 %r<8>;
    .reg .u64 %rd<8>;
    ld.param.u64 %rd1, [a];
    mov.u32 %r1, %tid.x;
    mul.wide.u32 %rd2, %r1, 4;
    st.shared.u32 [%rd2], %r1;
    mov.u64 %rd4, 128;
    st.shared.u32 [%rd4], %r1;
    add.u32 %r2, %r1, 1;
    mul.wide.u32 %rd3, %r2, 4;
    ld.shared.u32 %r3, [%rd3];
    add.u64 %rd5, %rd1, %rd2;
    st.global.u32 [%rd5], %r3;
    ret;
}
"""


def test_two_stores_racing_one_load_stay_distinct():
    """Both unsynchronized stores race the load; the operand detail in
    the dedup key keeps the two same-coded, same-uid diagnostics from
    collapsing into one."""
    from repro.core.analysis.lint import lint_source
    from repro.core.driver import Compiler

    findings = [f for f in lint_source(TWO_RACES_PTX)
                if f.code == "shared-race"]
    assert len(findings) == 2
    assert len({f.detail for f in findings}) == 2
    assert len({f.location for f in findings}) == 2

    with Compiler(jobs=0, lint="warn") as cc:
        result = cc.compile(TWO_RACES_PTX, cache=None)
    coded = [d for d in result.diagnostics if d.code == "shared-race"]
    assert len(coded) == 2


# ---------------------------------------------------------------------------
# service counters
# ---------------------------------------------------------------------------

def test_service_reports_proven_masks():
    from repro.launch.ptx_service import PtxServiceClient, PtxServiceServer

    with PtxServiceServer(port=0, jobs=0) as server:
        server.start()
        client = PtxServiceClient(server.host, server.port)
        reply = client.lint(ptx=_corpus("mask_reg_full.ptx"))
        assert reply["clean"] is True          # a NOTE never fails
        assert [f["code"] for f in reply["findings"]] \
            == ["membermask-proven"]
        assert reply["counts"]["lint_membermask_proven"] == 1
        stats = client.stats()
        assert stats["lint_counters"]["lint_membermask_proven"] == 1
