"""Equality-saturation middle-end integration tests (PR 7).

Pins the three contracts the saturation subsystem makes:

* ``saturate=off`` (the default) is byte-identical to the pre-PR
  pipeline — checked against the committed emulator golden file;
* ``saturate=on`` rewrites are *sound*: zero differential soundness
  failures over the KernelGen subset, and the gate itself provably
  catches a planted miscompile;
* the plumbing holds: the flag is part of the cache token, ``sat_*``
  counters ride reports/results separately from emulator counters, a
  gate failure surfaces as a WARNING diagnostic, and ``GET /stats``
  exposes both counter families.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

import pytest

from repro.core.driver import Compiler, Severity
from repro.core.frontend.kernelgen import all_benches, get_bench
from repro.core.frontend.stencil import lower_to_ptx
from repro.core.passes.context import PipelineConfig
from repro.core.ptx import print_kernel

from emulator_golden import GOLDEN_PATH

# enough kernels to satisfy the ">= 3 with positive predicted delta"
# acceptance bar without compiling the whole suite twice in tests (the
# benchmarks/saturation_smoke.py job covers all 16)
SATURATE_SUBSET = ["divergence", "gradient", "jacobi", "matmul",
                   "matvec", "vecadd"]


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as fh:
        return json.load(fh)


@pytest.fixture(scope="module")
def saturated():
    """name -> (off result, on result), one shared session each way."""
    out = {}
    with Compiler(jobs=0) as off, Compiler(jobs=0, saturate=True) as on:
        for name in SATURATE_SUBSET:
            b = get_bench(name)
            out[name] = (off.compile(b, cache=None, max_delta=b.max_delta),
                         on.compile(b, cache=None, max_delta=b.max_delta))
    return out


# ---------------------------------------------------------------------------
# saturate=off byte-identity with the golden file
# ---------------------------------------------------------------------------

def test_saturate_off_matches_emulator_golden(golden):
    """The default pipeline (saturation passes absent) must keep every
    KernelGen kernel byte-identical to the pre-saturation golden."""
    with Compiler(jobs=0) as cc:
        for name, b in sorted(all_benches().items()):
            r = cc.compile(b, cache=None, max_delta=b.max_delta)
            sha = hashlib.sha256(
                print_kernel(r.module.kernels[0]).encode()).hexdigest()
            assert sha == golden[f"kernelgen:{name}"]["ptx_sha256"], \
                f"{name}: saturate=off PTX drifted from golden"
            assert not r.saturation_counters, \
                f"{name}: sat_* counters leaked into a saturate=off run"


# ---------------------------------------------------------------------------
# cache-token and option plumbing
# ---------------------------------------------------------------------------

def test_cache_token_distinguishes_saturate():
    off = PipelineConfig()
    on = PipelineConfig(saturate=True)
    assert off.cache_token() != on.cache_token()


def test_same_session_off_then_on_not_cross_served():
    """off/on must occupy distinct cache entries: the second compile
    re-runs the pipeline instead of serving the off-entry."""
    with Compiler(jobs=0) as cc:
        r_off = cc.compile(get_bench("vecadd"))
        r_on = cc.compile(get_bench("vecadd"), saturate=True)
        assert not r_on.reports[0].cached
        assert r_on.ptx != r_off.ptx       # vecadd has extractable rewrites
        # and the off entry still serves
        assert cc.compile(get_bench("vecadd")).reports[0].cached


# ---------------------------------------------------------------------------
# saturate=on: soundness + predicted gains
# ---------------------------------------------------------------------------

def test_zero_soundness_failures(saturated):
    for name, (_off, on) in saturated.items():
        sc = on.saturation_counters
        assert sc.get("sat_soundness_failures", 0) == 0, \
            f"{name}: a rewrite failed the differential gate"
        assert not on.diagnostics_at(Severity.WARNING)


def test_positive_predicted_delta_on_at_least_three(saturated):
    positive = [name for name, (_off, on) in saturated.items()
                if on.saturation_counters.get("sat_cycle_delta_milli", 0) > 0]
    assert len(positive) >= 3, f"only {positive} improved"


def test_saturation_counters_populated_and_separated(saturated):
    _off, on = saturated["matmul"]
    sc = on.saturation_counters
    assert sc["sat_rewrites"] > 0 and sc["sat_deleted_instrs"] >= 0
    assert sc["sat_eclasses"] > 0 and sc["sat_enodes"] >= sc["sat_eclasses"]
    assert all(k.startswith("sat_") for k in sc)
    assert not any(k.startswith("sat_") for k in on.emulator_counters)
    # per-report counters carry both families for the service aggregate
    rep = on.reports[0]
    assert any(k.startswith("sat_") for k in rep.counters)


def test_rewritten_kernels_still_compile_and_detect(saturated):
    for name, (off, on) in saturated.items():
        assert on.reports[0].detection is not None
        assert on.reports[0].detection.n_flows > 0, \
            f"{name}: saturation broke downstream detection"


# ---------------------------------------------------------------------------
# the differential gate itself
# ---------------------------------------------------------------------------

def test_differential_gate_accepts_true_rewrite():
    from repro.core.egraph.extract import extract_kernel
    from repro.core.egraph.saturate import run_saturate
    from repro.core.egraph.verify import differential_check
    from repro.core.passes.context import KernelContext
    from repro.core.targets import resolve_target

    k = lower_to_ptx(get_bench("vecadd").program)
    ctx = KernelContext(k, PipelineConfig(saturate=True))
    run_saturate(ctx)
    res = extract_kernel(ctx.kernel, ctx.products.pop("_egraph_state"),
                         resolve_target(None))
    assert res.rewrites > 0
    assert differential_check(ctx.kernel, res.kernel) is None


def test_differential_gate_catches_planted_miscompile():
    """Flip one integer op in the 'rewritten' kernel: the gate must
    report a divergence (or a faulting run), never equivalence."""
    import copy

    from repro.core.egraph.verify import differential_check

    k = lower_to_ptx(get_bench("vecadd").program)
    broken = copy.copy(k)
    body = list(k.body)
    for i, stmt in enumerate(body):
        if getattr(stmt, "opcode", "") == "add.f32":
            body[i] = dataclasses.replace(stmt, opcode="sub.f32")
            break
    else:                                           # pragma: no cover
        pytest.fail("vecadd lost its add.f32")
    broken.body = body
    reason = differential_check(k, broken)
    assert reason is not None


def test_gate_failure_drops_rewrite_and_warns(monkeypatch):
    """When the gate rejects, the original kernel must ship, the
    failure must be counted, and a WARNING diagnostic attached."""
    from repro.core.egraph import verify as verify_mod

    # run_extract late-imports the gate from .verify, so patching the
    # verify module attribute intercepts it
    monkeypatch.setattr(verify_mod, "differential_check",
                        lambda *a, **k: "planted gate failure")
    with Compiler(jobs=0) as base:
        r_off = base.compile(get_bench("vecadd"), cache=None)
    with Compiler(jobs=0, saturate=True) as cc:
        r = cc.compile(get_bench("vecadd"), cache=None)
    assert r.ptx == r_off.ptx              # rewrite dropped, original kept
    assert r.saturation_counters["sat_soundness_failures"] == 1
    warnings = r.diagnostics_at(Severity.WARNING)
    assert any("soundness gate" in d.message for d in warnings)


# ---------------------------------------------------------------------------
# service surface
# ---------------------------------------------------------------------------

def test_stats_endpoint_exposes_saturation_counters(tmp_path):
    from repro.launch.ptx_service import PtxServiceClient, PtxServiceServer

    with PtxServiceServer(port=0, jobs=0,
                          cache_dir=str(tmp_path / "cache")) as srv:
        srv.start()
        client = PtxServiceClient(srv.host, srv.port)
        client.compile(bench="vecadd")
        st = client.stats()
        assert "emulator_counters" in st and "saturation_counters" in st
        assert st["emulator_counters"].get("steps", 0) > 0
        assert st["saturation_counters"] == {}     # nothing saturated yet
        client.compile(bench="vecadd", saturate=True)
        st = client.stats()
        sc = st["saturation_counters"]
        assert sc.get("sat_rewrites", 0) > 0
        assert sc.get("sat_soundness_failures", 0) == 0
        assert not any(k.startswith("sat_")
                       for k in st["emulator_counters"])
