"""SSD Pallas kernel: shape/dtype sweeps vs the chunked-scan oracle,
including the cross-chunk VMEM-scratch state carry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ssd_pallas, ssd_ref

RNG = np.random.default_rng(0)


def _inputs(B, L, H, P, N, dtype):
    xh = jnp.asarray(RNG.standard_normal((B, L, H, P)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, L, 1, N)), dtype)
    Cm = jnp.asarray(RNG.standard_normal((B, L, 1, N)), dtype)
    return xh, dt, A, Bm, Cm


@pytest.mark.parametrize("shape", [(2, 64, 4, 16, 16, 16),
                                   (1, 128, 2, 32, 64, 32),
                                   (2, 96, 3, 8, 16, 32),
                                   (1, 64, 2, 16, 16, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_matches_oracle(shape, dtype):
    B, L, H, P, N, Q = shape
    xh, dt, A, Bm, Cm = _inputs(B, L, H, P, N, dtype)
    ref = ssd_ref(xh, dt, A, Bm, Cm, chunk=Q)
    out = ssd_pallas(xh, dt, A, Bm, Cm, chunk=Q)
    tol = 1e-4 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_ssd_state_carries_across_chunks():
    """Single long chunk == many short chunks (scratch carry exactness)."""
    B, L, H, P, N = 1, 64, 2, 8, 16
    xh, dt, A, Bm, Cm = _inputs(B, L, H, P, N, jnp.float32)
    one = ssd_pallas(xh, dt, A, Bm, Cm, chunk=64)
    many = ssd_pallas(xh, dt, A, Bm, Cm, chunk=8)
    np.testing.assert_allclose(np.asarray(one), np.asarray(many),
                               rtol=2e-4, atol=2e-4)
