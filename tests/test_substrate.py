"""Substrate tests: optimizer, data pipeline, checkpointing, runtime
health, sharding rules, HLO analyzer."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # degrade: property tests skip
    from _hypothesis_stub import given, settings, st

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, TokenPipeline
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_host_mesh
from repro.runtime import Heartbeat, StragglerDetector, plan_elastic
from repro.sharding.rules import resolve_spec
from repro.train import OptConfig, adamw_update, init_opt_state
from repro.train.optim import global_norm, lr_schedule


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = OptConfig(lr=0.1, warmup_steps=1, total_steps=100,
                    weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}       # d/dw ||w||^2
        params, state, _ = adamw_update(cfg, grads, state, params)
    assert float(jnp.sum(jnp.abs(params["w"]))) < 0.5


def test_grad_clipping():
    cfg = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    big = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(cfg, big, state, params)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[1] == pytest.approx(0.5)     # mid-warmup
    assert lrs[2] == pytest.approx(1.0)     # peak
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    a = TokenPipeline(cfg).batch_at(3)
    b = TokenPipeline(cfg).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = TokenPipeline(cfg).batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50), st.sampled_from([1, 2, 4]))
def test_data_host_slicing(step, hosts):
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=8, seed=1)
    pipe = TokenPipeline(cfg)
    full = pipe.batch_at(step)
    per = cfg.global_batch // hosts
    for h in range(hosts):
        part = pipe.batch_at(step, host_slice=(h, hosts))
        np.testing.assert_array_equal(
            part["tokens"], full["tokens"][h * per:(h + 1) * per])


def test_labels_shifted():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    b = TokenPipeline(cfg).batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 8)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    state = {"p": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "n": jnp.asarray(3)}
    store.save(10, state, extra={"data_step": 10})
    assert store.latest_step() == 10
    got, extra = store.restore(10, state)
    np.testing.assert_array_equal(np.asarray(got["p"]), np.asarray(state["p"]))
    assert extra["data_step"] == 10


def test_checkpoint_atomicity(tmp_path):
    """A half-written (no manifest) checkpoint is never 'latest'."""
    store = CheckpointStore(str(tmp_path))
    state = {"p": jnp.ones(4)}
    store.save(1, state)
    # simulate a crash mid-write of step 2
    broken = tmp_path / "step_2"
    broken.mkdir()
    (broken / "leaf_00000.npy").write_bytes(b"garbage")
    assert store.latest_step() == 1


def test_checkpoint_corruption_detected(tmp_path):
    store = CheckpointStore(str(tmp_path))
    state = {"p": jnp.ones(4)}
    store.save(1, state)
    # flip bytes in the stored leaf
    leaf = tmp_path / "step_1" / "leaf_00000.npy"
    data = bytearray(leaf.read_bytes())
    data[-1] ^= 0xFF
    leaf.write_bytes(bytes(data))
    with pytest.raises(IOError):
        store.restore(1, state)


def test_checkpoint_async_and_gc(tmp_path):
    store = CheckpointStore(str(tmp_path))
    state = {"p": jnp.ones(4)}
    for s in (1, 2, 3, 4):
        store.save_async(s, state)
    store.wait()
    assert store.latest_step() == 4
    store.gc(keep=2)
    assert store.latest_step() == 4
    assert not (tmp_path / "step_1").exists()


def test_checkpoint_reshard_on_load(tmp_path):
    """Mesh-shape independence: restore with explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_host_mesh()
    store = CheckpointStore(str(tmp_path))
    state = {"p": jnp.arange(8, dtype=jnp.float32)}
    store.save(5, state)
    sh = {"p": NamedSharding(mesh, P())}
    got, _ = store.restore(5, state, shardings=sh)
    assert got["p"].sharding == sh["p"]


# ---------------------------------------------------------------------------
# runtime health
# ---------------------------------------------------------------------------

def test_heartbeat_death_detection():
    hb = Heartbeat(["a", "b"], lease_s=10.0)
    hb.beat("a", 5, now=100.0)
    hb.beat("b", 5, now=100.0)
    assert hb.dead_hosts(now=105.0) == []
    hb.beat("a", 6, now=115.0)
    assert hb.dead_hosts(now=115.0) == ["b"]
    assert hb.watermark() == 5


def test_straggler_detection():
    det = StragglerDetector(threshold=1.5, patience=2)
    t_ok = {"a": 1.0, "b": 1.0, "c": 1.0}
    t_slow = {"a": 1.0, "b": 1.0, "c": 2.5}
    assert det.observe_step(t_ok) == []
    assert det.observe_step(t_slow) == []        # patience 1/2
    assert det.observe_step(t_slow) == ["c"]     # flagged
    assert det.observe_step(t_ok) == []          # streak reset


def test_elastic_plan():
    plan = plan_elastic([f"h{i}" for i in range(128)], chips_per_host=4,
                        model_axis=16)
    assert plan.mesh_shape == (32, 16)           # 512 chips
    plan2 = plan_elastic([f"h{i}" for i in range(100)], chips_per_host=4)
    assert plan2.mesh_shape == (16, 16)          # shrink to 256 chips
    assert len(plan2.host_slices) == 64


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

class _FakeMesh:
    """resolve_spec only reads mesh.shape; avoids needing real devices."""

    def __init__(self, **axes):
        self.shape = dict(axes)


def test_resolve_spec_divisibility_fallback():
    from jax.sharding import PartitionSpec as P
    mesh = make_host_mesh()   # (1,1): everything divides
    spec = resolve_spec((64, 32), ("vocab", "embed"), mesh)
    assert spec == P("model", "data")
    # 4 kv heads cannot shard over a 16-wide model axis
    mesh16 = _FakeMesh(data=1, model=16)
    spec = resolve_spec((64, 4, 8), ("embed", "kv_heads", "head_dim"), mesh16)
    assert len(spec) < 2 or spec[1] is None      # kv replicated
    rep = []
    resolve_spec((64, 4, 8), ("embed", "kv_heads", "head_dim"), mesh16,
                 report=rep)
    assert any("kv_heads" in r for r in rep)


def test_resolve_spec_no_duplicate_axis():
    from jax.sharding import PartitionSpec as P
    mesh = _FakeMesh(data=2, model=2)
    # two dims both mapped to 'model': second must fall back
    spec = resolve_spec((8, 8), ("vocab", "ff"), mesh)
    assert spec == P("model")


# ---------------------------------------------------------------------------
# HLO analyzer (trip-count correction)
# ---------------------------------------------------------------------------

_HLO = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %dot.1)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%z, %a)
  %w2 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16] get-tuple-element(%w2), index=1
}
"""


def test_hlo_analyzer_trip_scaling():
    stats = analyze(_HLO)
    # one dot of 2*8*16*16 flops, executed 12 times
    assert stats.flops == pytest.approx(12 * 2 * 8 * 16 * 16)
    assert stats.n_while == 1 and stats.trip_counts == [12]


def test_hlo_analyzer_collectives():
    hlo = _HLO.replace(
        "ROOT %out = f32[8,16] get-tuple-element(%w2), index=1",
        "%g = f32[8,16] get-tuple-element(%w2), index=1\n"
        "  ROOT %ar = f32[8,16] all-reduce(%g), to_apply=%cond")
    stats = analyze(hlo)
    assert stats.collective_bytes["all-reduce"] == pytest.approx(8 * 16 * 4)
