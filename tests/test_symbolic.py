"""Unit + property tests for the symbolic term algebra and SMT-lite solver."""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # degrade: property tests skip
    from _hypothesis_stub import given, settings, st

from repro.core.symbolic import (AssumptionSet, Cmp, Sym, Term, FALSE, TRUE,
                                 solve_shift, to_signed)
from repro.core.symbolic.solver import may_alias


W = 32
consts = st.integers(min_value=-(2**31), max_value=2**31 - 1)
small = st.integers(min_value=-100, max_value=100)


def t_const(v):
    return Term.const_(v, W)


@st.composite
def affine_terms(draw):
    syms = [Sym(f"s{i}", W) for i in range(3)]
    t = t_const(draw(small))
    for s in syms:
        c = draw(small)
        if c:
            t = t.add(Term.atom(s, W).mul_const(c))
    return t


@settings(max_examples=50, deadline=None)
@given(affine_terms(), affine_terms())
def test_add_commutes(a, b):
    assert a.add(b) == b.add(a)


@settings(max_examples=50, deadline=None)
@given(affine_terms(), affine_terms(), affine_terms())
def test_add_associates(a, b, c):
    assert a.add(b).add(c) == a.add(b.add(c))


@settings(max_examples=50, deadline=None)
@given(affine_terms())
def test_sub_self_is_zero(a):
    d = a.sub(a)
    assert d.is_const and d.const == 0


@settings(max_examples=50, deadline=None)
@given(affine_terms(), small, small)
def test_mul_const_distributes(a, k1, k2):
    assert a.mul_const(k1).add(a.mul_const(k2)) == a.mul_const(k1 + k2)


@settings(max_examples=50, deadline=None)
@given(consts)
def test_signed_roundtrip(v):
    assert to_signed(v & 0xFFFFFFFF, 32) == \
        (v if -(2**31) <= v < 2**31 else to_signed(v & 0xFFFFFFFF, 32))


# ---------------------------------------------------------------------------
# solve_shift — the paper's delta equation (Section 5.1)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(-31, 31), st.integers(1, 16),
       st.integers(-1000, 1000))
def test_solve_shift_finds_planted_delta(n, stride_elems, base):
    lane = Sym("tid.x", 64)
    k = 4 * stride_elems
    src = t_const64(base).add(Term.atom(lane, 64).mul_const(k))
    dst = src.add(t_const64(n * k))     # dst(lane) == src(lane + n)
    assert solve_shift(src, dst, lane) == n


def t_const64(v):
    return Term.const_(v, 64)


def test_solve_shift_rejects_mismatched_stride():
    lane = Sym("tid.x", 64)
    src = Term.atom(lane, 64).mul_const(4)
    dst = Term.atom(lane, 64).mul_const(8)
    assert solve_shift(src, dst, lane) is None


def test_solve_shift_rejects_lane_invariant():
    lane = Sym("tid.x", 64)
    other = Sym("j", 64)
    src = Term.atom(other, 64).mul_const(4)
    dst = src.add(t_const64(4))
    assert solve_shift(src, dst, lane) is None


def test_solve_shift_out_of_warp_range():
    lane = Sym("tid.x", 64)
    src = Term.atom(lane, 64).mul_const(4)
    dst = src.add(t_const64(4 * 32))    # N = 32 > 31
    assert solve_shift(src, dst, lane) is None


def test_solve_shift_paper_worked_example():
    """Section 5.1 worked example: two taps of the same row two lanes
    apart solve to N = -2 (shfl.up by 2)."""
    lane = Sym("tid.x", 64)             # paper's thread dim (i)
    base = Sym("w0", 64)

    def addr(di):
        return (Term.atom(base, 64)
                .add(Term.atom(lane, 64).mul_const(4))
                .add(t_const64(4 * di)))

    src = addr(+1)       # w0(i+1, .) loaded first (ascending order)
    dst = addr(-1)       # w0(i-1, .) wants the value lane-2 already has
    assert solve_shift(src, dst, lane) == -2


# ---------------------------------------------------------------------------
# assumption sets (branch pruning)
# ---------------------------------------------------------------------------

def test_assumptions_contradiction():
    s = AssumptionSet()
    x = Term.sym("x", 32)
    assert s.add(Cmp("lt", x, t_const(10)))
    assert not s.add(Cmp("gt", x, t_const(20)))


def test_assumptions_entailment():
    s = AssumptionSet()
    x = Term.sym("x", 32)
    assert s.add(Cmp("lt", x, t_const(10)))
    assert s.implied(Cmp("lt", x, t_const(20))) is True
    assert s.implied(Cmp("ge", x, t_const(10))) is False
    assert s.implied(Cmp("lt", x, t_const(5))) is None


def test_assumptions_eq_ne_interplay():
    s = AssumptionSet()
    x = Term.sym("y", 32)
    assert s.add(Cmp("eq", x, t_const(7)))
    assert s.implied(Cmp("ne", x, t_const(7))) is False
    assert not s.add(Cmp("ne", x, t_const(7)))


def test_may_alias():
    a = Term.sym("p", 64)
    assert may_alias(a, a)
    assert not may_alias(a, a.add(Term.const_(4, 64)))
    b = Term.sym("q", 64)
    assert may_alias(a, b)      # unknown difference: conservative
