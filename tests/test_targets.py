"""Target-subsystem tests: registry resolution, target-aware codegen
(golden ``shfl.sync`` vs legacy ``shfl`` encodings), cost-model-guided
selection (per-target keep/drop agreeing with the concrete-emulation
cycle model), ``compile_for_targets`` prefix sharing, the speedup-table
guard rails, and LRU cache eviction."""

import numpy as np
import pytest

import repro.core.passes.analyses as analyses_mod
from repro.core.emulator.concrete import RunStats, run_concrete
from repro.core.emulator.cycles import estimate_cycles, speedup_table
from repro.core.emulator.machine import emulate
from repro.core.frontend.kernelgen import get_bench
from repro.core.frontend.stencil import lower_to_ptx
from repro.core.passes import (
    CompileCache,
    KernelReport,
    PipelineConfig,
    compile_for_targets,
    compile_kernel,
    compile_ptx,
)
from repro.core.ptx import parse_kernel, print_kernel, print_module
from repro.core.ptx.ir import Module
from repro.core.synthesis.codegen import synthesize
from repro.core.synthesis.detect import detect
from repro.core.targets import (
    TargetProfile,
    all_targets,
    default_target,
    get_target,
    resolve_target,
    target_names,
)
from repro.core.targets.cost import measured_profit, score_pair, select


def _jacobi_kernel():
    return lower_to_ptx(get_bench("jacobi").program)


def _detection(kernel, max_delta=31):
    return detect(kernel, emulate(kernel), max_delta=max_delta)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_has_paper_generations_plus_extrapolations():
    names = target_names()
    assert {"kepler", "maxwell", "pascal", "volta"} <= set(names)
    assert len(names) >= 6
    # Table 1 values survive the data-card encoding
    volta = get_target("volta")
    assert volta.latency == dict(shfl=22, sm=19, l1=28)
    assert get_target("maxwell").latency["l1"] == 82
    assert get_target("ampere").calibration == "extrapolated"


def test_resolution_by_name_sm_and_directive():
    assert resolve_target("pascal").name == "pascal"
    assert resolve_target("sm_61").name == "pascal"
    assert resolve_target("sm_75").name == "volta"       # nearest below
    assert resolve_target("sm_999").name == "hopper"     # above the top
    assert resolve_target("sm_30").name == "kepler"      # same ISA era
    assert resolve_target("sm_90a, texmode_independent").name == "hopper"
    assert resolve_target(None) is default_target()
    prof = get_target("kepler")
    assert resolve_target(prof) is prof
    with pytest.raises(KeyError):
        resolve_target("turing-ish")
    with pytest.raises(KeyError, match="warp-shuffle"):
        resolve_target("sm_20")                          # pre-shuffle ISA


def test_default_target_matches_printer_fallback():
    d = default_target()
    text = print_module(Module())
    assert f".target {d.sm_name}" in text
    assert f".version {d.ptx_version}" in text
    assert f".address_size {d.address_size}" in text


# ---------------------------------------------------------------------------
# target-aware codegen (golden encodings)
# ---------------------------------------------------------------------------

def test_codegen_sync_encoding_on_sm70_plus():
    kernel = _jacobi_kernel()
    det = _detection(kernel)
    for name in ("volta", "ampere", "hopper"):
        text = print_kernel(synthesize(kernel, det, target=name))
        shfl_lines = [l for l in text.splitlines() if "shfl." in l]
        assert shfl_lines, name
        assert all("shfl.sync." in l and l.rstrip(";").endswith("0xffffffff")
                   for l in shfl_lines), (name, shfl_lines)


def test_codegen_legacy_encoding_below_sm70():
    kernel = _jacobi_kernel()
    det = _detection(kernel)
    for name in ("kepler", "maxwell", "pascal"):
        text = print_kernel(synthesize(kernel, det, target=name))
        shfl_lines = [l for l in text.splitlines() if "shfl." in l]
        assert shfl_lines, name
        assert all("sync" not in l and "0xffffffff" not in l
                   for l in shfl_lines), (name, shfl_lines)
        # legacy form: dst, src, |N|, clamp — exactly 4 operands
        assert all(len(l.split(",")) == 4 for l in shfl_lines)


def test_codegen_warp_width_from_profile():
    wide = TargetProfile(
        name="wide64", sm=100, arch="hypothetical", warp_width=64,
        latency=dict(shfl=20, sm=20, l1=30), mlp=8.0, has_shfl_sync=True)
    kernel = _jacobi_kernel()
    text = print_kernel(synthesize(kernel, _detection(kernel), target=wide))
    assert "rem.u32 %sflwid0, %sflwid0, 64;" in text
    assert "0xffffffffffffffff" in text          # full 64-lane membermask
    assert ", 63," in text                       # down-clamp = width - 1


def test_legacy_encoding_is_bit_exact_on_the_emulator():
    b = get_bench("laplacian")
    prog = b.program
    kernel = lower_to_ptx(prog)
    det = _detection(kernel, max_delta=b.max_delta)
    assert det.n_shuffles > 0
    legacy = synthesize(kernel, det, mode="ptxasw", target="maxwell")
    nd = prog.ndim
    shape = {2: (6, 70), 3: (5, 6, 70)}[nd]
    h = prog.halo
    grid = (-(-(shape[-1] - 2 * h[0]) // 64),
            shape[-2] - 2 * h[1] if nd >= 2 else 1,
            shape[0] - 2 * h[2] if nd == 3 else 1)
    outs = []
    for k in (kernel, legacy):
        rng = np.random.default_rng(0)
        params = {}
        for arr, adim in prog.arrays.items():
            params[arr] = (np.zeros(shape[-adim:], np.float32)
                           if arr == prog.out.array else
                           rng.standard_normal(shape[-adim:])
                           .astype(np.float32))
        for d in range(nd):
            params[f"n{d}"] = shape[::-1][d]
        for s in prog.scalars:
            params[s] = int(np.frombuffer(
                np.float32(0.3).tobytes(), np.uint32)[0])
        stats = run_concrete(k, params, ntid=(64, 1, 1), nctaid=grid)
        outs.append(params[prog.out.array].copy())
    assert np.array_equal(outs[0], outs[1])
    assert stats.get("shfl") > 0                 # the legacy form executed


def test_module_target_directive_elects_profile():
    kernel = _jacobi_kernel()
    body = print_kernel(kernel)
    legacy_out, _ = compile_ptx(
        ".version 6.3\n.target sm_52\n.address_size 64\n\n" + body,
        cache=None)
    sync_out, _ = compile_ptx(
        ".version 7.6\n.target sm_70\n.address_size 64\n\n" + body,
        cache=None)
    assert "shfl.down.b32" in legacy_out and "sync" not in legacy_out
    assert "shfl.sync.down.b32" in sync_out
    # explicit config target overrides the directive
    forced, _ = compile_ptx(
        ".version 6.3\n.target sm_52\n.address_size 64\n\n" + body,
        PipelineConfig(target="volta"), cache=None)
    assert "shfl.sync" in forced


# ---------------------------------------------------------------------------
# cost-model-guided selection
# ---------------------------------------------------------------------------

def test_select_pass_rejects_on_volta_keeps_on_pascal():
    kernel = _jacobi_kernel()
    results = {}
    for name in ("volta", "pascal"):
        out, rep = compile_kernel(
            kernel, PipelineConfig(target=name, selection="cost"),
            cache=None)
        results[name] = (out, rep)
    volta_rep = results["volta"][1]
    pascal_rep = results["pascal"][1]
    assert pascal_rep.selection.n_dropped == 0
    assert pascal_rep.detection.n_shuffles == 6
    assert volta_rep.selection.n_dropped >= 1
    assert volta_rep.detection.n_shuffles < 6
    # the dropped candidates exist in pascal's output, not volta's
    assert "shfl." in print_kernel(results["pascal"][0])
    assert "shfl" not in print_kernel(results["volta"][0])
    assert volta_rep.target == "volta" and pascal_rep.target == "pascal"


def test_selection_all_is_default_and_identity():
    kernel = _jacobi_kernel()
    out_default, rep = compile_kernel(kernel, PipelineConfig(), cache=None)
    assert rep.selection is None
    assert rep.detection.n_shuffles == 6
    legacy = synthesize(kernel, _detection(kernel), mode="ptxasw")
    assert print_kernel(out_default) == print_kernel(legacy)


def test_selection_decision_matches_concrete_cycle_model():
    """The static gate must agree with emulated reality: synthesis wins
    on Pascal and loses on Volta, per the same cycle model applied to
    concrete-emulation event counts."""
    b = get_bench("jacobi")
    kernel = lower_to_ptx(b.program)
    det = _detection(kernel)
    syn = synthesize(kernel, det, mode="ptxasw")

    def run(k):
        rng = np.random.default_rng(0)
        ny, nx = 4, 1026               # lane-aligned interior
        cb = lambda v: int(np.frombuffer(
            np.float32(v).tobytes(), np.uint32)[0])
        params = {"w0": rng.standard_normal((ny, nx)).astype(np.float32),
                  "w1": np.zeros((ny, nx), np.float32),
                  "n0": nx, "n1": ny,
                  "c0": cb(.5), "c1": cb(.25), "c2": cb(.125)}
        return run_concrete(k, params, ntid=(512, 1, 1),
                            nctaid=(2, ny - 2, 1))
    base, shuffled = run(kernel), run(syn)
    assert measured_profit(base, shuffled, "pascal") > 0   # shuffles win
    assert measured_profit(base, shuffled, "volta") < 0    # shuffles lose
    # and that is exactly what the per-pair scores predicted
    assert all(score_pair(p, "pascal").profitable for p in det.pairs)
    assert not any(score_pair(p, "volta").profitable
                   for p in det.pairs if p.delta != 0)


def test_select_report_scores_every_candidate():
    det = _detection(_jacobi_kernel())
    sel = select(det, "maxwell")
    assert len(sel.scores) == det.n_shuffles == 6
    assert sel.n_kept == 6 and sel.n_dropped == 0
    assert sel.selected.n_loads == det.n_loads
    kepler = select(det, "kepler")
    assert kepler.n_kept < 6
    assert all(s.profit <= 0 for s in kepler.dropped)


# ---------------------------------------------------------------------------
# compile_for_targets
# ---------------------------------------------------------------------------

def _count_emulate(monkeypatch):
    calls = []

    def counting(kernel, **kw):
        calls.append(kernel.name)
        return emulate(kernel, **kw)

    monkeypatch.setattr(analyses_mod, "emulate", counting)
    return calls


def test_compile_for_targets_per_arch_variants(monkeypatch):
    calls = _count_emulate(monkeypatch)
    texts = [print_kernel(lower_to_ptx(get_bench(n).program))
             for n in ("jacobi", "laplacian")]
    module_text = "\n".join(texts)
    cache = CompileCache()
    variants = compile_for_targets(module_text, selection="cost",
                                   cache=cache, jobs=1)
    assert len(variants) >= 6
    # the target-independent prefix ran once per kernel, not per target
    assert sorted(calls) == ["jacobi", "laplacian"]
    for name, v in variants.items():
        prof = v.target
        assert f".target {prof.sm_name}" in v.ptx
        assert f".version {prof.ptx_version}" in v.ptx
        shfl_lines = [l for l in v.ptx.splitlines() if "shfl." in l]
        if prof.has_shfl_sync:
            assert all("shfl.sync." in l for l in shfl_lines)
        else:
            assert all("sync" not in l for l in shfl_lines)
        assert [r.name for r in v.reports] == ["jacobi", "laplacian"]
    # the chosen sets differ across architectures as the model predicts
    assert variants["pascal"].n_shuffles == 8      # 6 + 2, all kept
    assert variants["volta"].n_shuffles < variants["pascal"].n_shuffles
    assert variants["maxwell"].n_shuffles == variants["pascal"].n_shuffles


def test_compile_for_targets_subset_and_parallel():
    text = print_kernel(_jacobi_kernel())
    variants = compile_for_targets(text, targets=["pascal", "sm_70"],
                                   cache=None, jobs=2)
    assert set(variants) == {"pascal", "volta"}
    assert "shfl.down.b32" in variants["pascal"].ptx
    assert "shfl.sync.down.b32" in variants["volta"].ptx


# ---------------------------------------------------------------------------
# speedup_table guard rails (satellite)
# ---------------------------------------------------------------------------

def test_speedup_table_requires_original():
    with pytest.raises(ValueError, match="original"):
        speedup_table({"ptxasw": RunStats()})


def test_speedup_table_zero_cycles_no_division_error():
    empty = RunStats()
    loaded = RunStats(counts={"load_global": 10})
    table = speedup_table({"original": loaded, "noload": empty},
                          targets=["volta"])
    assert table["volta"]["noload"] == float("inf")
    degenerate = speedup_table({"original": empty, "other": empty},
                               targets=["volta"])
    assert degenerate["volta"]["other"] == 1.0


def test_estimate_cycles_accepts_profile_and_name():
    stats = RunStats(counts={"load_global": 6, "shfl": 3, "alu": 10})
    by_name = estimate_cycles(stats, "pascal")
    by_prof = estimate_cycles(stats, get_target("pascal"))
    assert by_name.cycles == by_prof.cycles
    assert by_name.arch == "pascal"


# ---------------------------------------------------------------------------
# LRU cache (satellite)
# ---------------------------------------------------------------------------

def test_compile_cache_lru_not_fifo():
    cache = CompileCache(max_entries=2)
    kernel = parse_kernel(print_kernel(_jacobi_kernel()))
    report = KernelReport(name="k")
    ka = cache.key("a", PipelineConfig(), ("p",))
    kb = cache.key("b", PipelineConfig(), ("p",))
    kc = cache.key("c", PipelineConfig(), ("p",))
    cache.put(ka, kernel, report)
    cache.put(kb, kernel, report)
    assert cache.get(ka) is not None     # touch a: now b is the LRU entry
    cache.put(kc, kernel, report)        # evicts b (FIFO would evict a)
    assert cache.get(kb) is None
    assert cache.get(ka) is not None
    assert cache.stats.evictions == 1
    assert 0 < cache.stats.hit_rate < 1


# ---------------------------------------------------------------------------
# src_share fixed point (headline satellite)
# ---------------------------------------------------------------------------

# Engineered so the share cascade flips decisions: at the stale
# all-candidates split (3 sharers) deltas 1 and 2 look profitable and 3
# does not; re-scoring the survivors with the post-drop split rejects
# delta 2 as well, and delta 1 alone stays profitable at share 1.
_SHARE_FIXTURE = TargetProfile(
    name="fxshare", sm=61, arch="share fixture",
    latency=dict(shfl=128, sm=20, l1=160), mlp=1.0,
    has_shfl_sync=False, shfl_ilp=1.0,
    alu_cost=5.0, pred_off_cost=0.0)


def _four_tap_kernel():
    """out[i] = w[i] + w[i+1] + w[i+2] + w[i+3]: one source load shared
    by three covered loads (deltas 1, 2, 3)."""
    from repro.core.frontend.stencil import Array, I, Program

    w = Array("w0")
    expr = w[I()] + w[I(1)] + w[I(2)] + w[I(3)]
    return lower_to_ptx(Program(name="sharer4", ndim=1,
                                out=Array("out")[I()], expr=expr))


def test_select_recomputes_src_share_over_kept_set():
    from repro.core.synthesis.detect import DetectionResult, ShufflePair

    pairs = [ShufflePair(dst_uid=10 + n, src_uid=1, delta=n)
             for n in (1, 2, 3)]
    det = DetectionResult(pairs=pairs, n_loads=4)
    # the stale all-candidates split keeps delta 2...
    assert score_pair(pairs[1], _SHARE_FIXTURE, src_share=3).profitable
    # ...but the fixed point rejects it: after delta 3 drops, delta 2
    # re-scores at share 2 and loses
    sel = select(det, _SHARE_FIXTURE)
    assert [p.delta for p in sel.selected.pairs] == [1]
    by_delta = {s.pair.delta: s for s in sel.scores}
    assert by_delta[1].profitable
    assert not by_delta[2].profitable and not by_delta[3].profitable
    # the survivor carries the final share-1 score (capture not split)
    assert by_delta[1].shuffled_cycles == pytest.approx(
        score_pair(pairs[0], _SHARE_FIXTURE, src_share=1).shuffled_cycles)


def test_fixed_point_profit_sums_match_measured_profit():
    """Whole-kernel predicted profit of the *kept* set must equal the
    concrete-emulation cycle delta up to the 2-instruction prologue."""
    kernel = _four_tap_kernel()
    det = _detection(kernel)
    assert sorted(abs(p.delta) for p in det.pairs) == [1, 2, 3]
    assert len({p.src_uid for p in det.pairs}) == 1
    sel = select(det, _SHARE_FIXTURE)
    assert [p.delta for p in sel.selected.pairs] == [1]

    variant = synthesize(kernel, sel.selected, mode="ptxasw",
                         target=_SHARE_FIXTURE)
    rng = np.random.default_rng(0)
    n0 = 38                       # interior = 32: one full, all-interior warp
    threads = 32

    def run(k):
        params = {"w0": rng.standard_normal(n0).astype(np.float32),
                  "out": np.zeros(n0, np.float32), "n0": n0}
        return run_concrete(k, params, ntid=(threads, 1, 1),
                            nctaid=(1, 1, 1))
    measured = measured_profit(run(kernel), run(variant), _SHARE_FIXTURE)
    predicted = sum(s.profit for s in sel.scores if s.profitable)
    prologue = 2 * _SHARE_FIXTURE.alu_cost * threads
    assert measured == pytest.approx(threads * predicted - prologue)
    assert abs(measured - threads * predicted) <= prologue + 1e-9
    # the stale model's books would not balance: it promises delta-2
    # profit codegen never delivers
    stale = sum(score_pair(p, _SHARE_FIXTURE, src_share=3).profit
                for p in det.pairs if p.delta in (1, 2))
    assert abs(measured - (threads * stale - prologue)) > 1.0


# ---------------------------------------------------------------------------
# CompileCache.clear keeps the stats object alive (satellite)
# ---------------------------------------------------------------------------

def test_cache_clear_resets_stats_in_place():
    cache = CompileCache(max_entries=2)
    kernel = parse_kernel(print_kernel(_jacobi_kernel()))
    held = cache.stats                  # benchmarks/run.py-style reference
    key = cache.key("a", PipelineConfig(), ("p",))
    cache.put(key, kernel, KernelReport(name="k"))
    assert cache.get(key) is not None and cache.get("absent") is None
    assert held.hits == 1 and held.misses == 1
    cache.clear()
    assert cache.stats is held          # same object, counters zeroed
    assert (held.hits, held.misses, held.evictions) == (0, 0, 0)
    assert len(cache) == 0
    cache.get(key)
    assert held.misses == 1             # and it keeps counting


def test_cache_token_distinguishes_target_and_selection():
    base = PipelineConfig()
    assert PipelineConfig(target="pascal").cache_token() \
        != base.cache_token()
    assert PipelineConfig(selection="cost").cache_token() \
        != base.cache_token()
    # resolution-equivalent specs share entries
    assert PipelineConfig(target="sm_61").cache_token() \
        == PipelineConfig(target="pascal").cache_token()
    # None resolves to the default profile, same as naming it
    assert base.cache_token() \
        == PipelineConfig(target=default_target().name).cache_token()
