"""Integration tests: training convergence, accumulation equivalence,
checkpoint resume continuity, serve generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model, unbox
from repro.serve import generate
from repro.train import OptConfig, init_opt_state, make_train_step


def _setup(arch="olmo-1b", **over):
    cfg = reduced(get_config(arch)).replace(**over)
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def test_training_reduces_loss():
    cfg, model, params = _setup()
    opt = OptConfig(lr=3e-3, warmup_steps=3, total_steps=40)
    state = init_opt_state(params)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                    global_batch=8))
    losses = []
    for s in range(40):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_grad_accumulation_equivalence():
    """accum_steps=2 over a 8-row batch == accum_steps=1, same grads."""
    cfg, model, params = _setup()
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                    global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    outs = []
    for accum in (1, 2):
        state = init_opt_state(params)
        step = jax.jit(make_train_step(model, opt, accum_steps=accum))
        p2, _, m = step(params, state, batch)
        outs.append((p2, float(m["loss"])))
    leaves0 = jax.tree_util.tree_leaves(outs[0][0])
    leaves1 = jax.tree_util.tree_leaves(outs[1][0])
    for a, b in zip(leaves0, leaves1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_checkpoint_resume_bitwise(tmp_path):
    """Stop at step 10, resume, and land on the same params as an
    uninterrupted run (determinism end to end)."""
    from repro.checkpoint import CheckpointStore
    cfg, model, params0 = _setup()
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                    global_batch=4))
    step = jax.jit(make_train_step(model, opt))

    def run(params, state, lo, hi):
        for s in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            params, state, _ = step(params, state, batch)
        return params, state

    # uninterrupted
    pA, sA = run(params0, init_opt_state(params0), 0, 20)
    # interrupted at 10 + resumed from checkpoint
    store = CheckpointStore(str(tmp_path))
    pB, sB = run(params0, init_opt_state(params0), 0, 10)
    store.save(10, (pB, sB), extra={"data_step": 10})
    (pB2, sB2), extra = store.restore(10, (pB, sB))
    pB3, _ = run(pB2, sB2, extra["data_step"], 20)
    for a, b in zip(jax.tree_util.tree_leaves(pA),
                    jax.tree_util.tree_leaves(pB3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_generate_shapes_and_determinism():
    cfg, model, params = _setup("zamba2-1.2b")
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=16,
                                    global_batch=2))
    batch = {"tokens": jnp.asarray(pipe.batch_at(0)["tokens"])}
    out1 = generate(model, params, dict(batch), n_tokens=6, max_len=24)
    out2 = generate(model, params, dict(batch), n_tokens=6, max_len=24)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
